//! Portable scalar microkernel — the fallback every target compiles.
//!
//! This is PR 1's proven `4×16` register tile, unchanged in spirit:
//! fixed-size accumulator arrays (`[[f32; NR]; MR]`, `chunks_exact` +
//! `try_into`) keep LLVM on the autovectorized path for whatever the
//! build target enables (SSE2 on stock x86-64 builds), with no `unsafe`
//! anywhere. It doubles as the numerical baseline the SIMD variants are
//! parity-tested against (beyond the `ops` reference oracle).

use super::{write_tile_edge, Epilogue, Isa, Kernel};

const MR: usize = 4;
const NR: usize = 16;

pub(super) static KERNEL: Kernel = Kernel {
    isa: Isa::Scalar,
    mr: MR,
    nr: NR,
    tile_fn: tile,
    matvec_fn: matvec_rows,
    relu_fn: relu_map,
    max_fn: max_into,
};

/// `MR×NR` register tile over packed panels; epilogue fused into the
/// final-k writeback via the shared edge path (which for the scalar
/// variant *is* the writeback).
#[allow(clippy::too_many_arguments)]
fn tile(
    ap: &[f32],
    bp: &[f32],
    kc: usize,
    c: &mut [f32],
    n: usize,
    row0: usize,
    col0: usize,
    rows: usize,
    cols: usize,
    ep: Option<Epilogue>,
) {
    debug_assert!(ap.len() >= kc * MR && bp.len() >= kc * NR);
    let mut acc = [[0.0f32; NR]; MR];
    for (av, bv) in ap.chunks_exact(MR).zip(bp.chunks_exact(NR)).take(kc) {
        let av: &[f32; MR] = av.try_into().unwrap();
        let bv: &[f32; NR] = bv.try_into().unwrap();
        for (accr, &a) in acc.iter_mut().zip(av.iter()) {
            for (dst, &b) in accr.iter_mut().zip(bv.iter()) {
                *dst += a * b;
            }
        }
    }
    let mut flat = [0.0f32; MR * NR];
    for (r, accr) in acc.iter().enumerate() {
        flat[r * NR..(r + 1) * NR].copy_from_slice(accr);
    }
    write_tile_edge(&flat, NR, c, n, row0, col0, rows, cols, ep);
}

/// Dense rows via an 8-lane dot product (lane sums keep LLVM on the
/// vector path). `k >= 1`.
fn matvec_rows(w: &[f32], x: &[f32], bias: Option<&[f32]>, relu: bool, y: &mut [f32], k: usize) {
    for (row, (w_row, out)) in w.chunks_exact(k).zip(y.iter_mut()).enumerate() {
        let mut s = dot(w_row, x);
        if let Some(b) = bias {
            s += b[row];
        }
        *out = if relu { s.max(0.0) } else { s };
    }
}

/// 8-lane dot product.
fn dot(w: &[f32], x: &[f32]) -> f32 {
    const L: usize = 8;
    let mut lanes = [0.0f32; L];
    let wc = w.chunks_exact(L);
    let xc = x.chunks_exact(L);
    let w_rem = wc.remainder();
    let x_rem = xc.remainder();
    for (wv, xv) in wc.zip(xc) {
        for ((lane, &a), &b) in lanes.iter_mut().zip(wv).zip(xv) {
            *lane += a * b;
        }
    }
    let mut s: f32 = lanes.iter().sum();
    for (&a, &b) in w_rem.iter().zip(x_rem) {
        s += a * b;
    }
    s
}

fn relu_map(src: &[f32], dst: &mut [f32]) {
    for (d, &s) in dst.iter_mut().zip(src) {
        *d = s.max(0.0);
    }
}

fn max_into(src: &[f32], dst: &mut [f32]) {
    for (d, &s) in dst.iter_mut().zip(src) {
        *d = d.max(s);
    }
}
