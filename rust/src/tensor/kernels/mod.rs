//! SIMD GEMM microkernels with runtime ISA dispatch.
//!
//! The blocked GEMM in `tensor::gemm` walks cache-level blocks and packed
//! micro-panels; the innermost register tile is ISA-specific and lives
//! here. Three variants are compiled (per target) and one is selected at
//! startup by runtime feature detection:
//!
//!  * **scalar** — the portable `4×16` fixed-array tile (LLVM
//!    autovectorizes it to whatever the build target allows, typically
//!    SSE2 on a stock `x86_64-unknown-linux-gnu` build);
//!  * **avx2** — x86-64 AVX2+FMA `6×16`: six accumulator rows of two
//!    256-bit lanes each (12 of 16 ymm registers), `std::arch`
//!    intrinsics, selected when `is_x86_feature_detected!` confirms
//!    `avx2` *and* `fma`;
//!  * **neon** — aarch64 NEON `8×8`: eight rows of two 128-bit lanes
//!    (16 of 32 v-registers).
//!
//! Each [`Kernel`] owns its tile geometry (`mr`/`nr`) — the packing code
//! in `tensor::gemm` derives panel layouts from the kernel, and
//! `PackedA` records which kernel it was packed for so prepacked compiled
//! plans always run on a matching microkernel. Besides the GEMM tile a
//! kernel carries the dense-layer matvec rows, the ReLU map, and the
//! elementwise running-max used by the fast maxpool — the whole per-ISA
//! surface sits behind one dispatch table.
//!
//! Selection: [`selected`] returns the auto-detected kernel, overridable
//! two ways — the `IOP_KERNEL` env var (`scalar|avx2|neon`, read once;
//! unknown/unsupported values panic with the supported list) for
//! CLI/bench processes, and [`force`] for in-process benchmarks that
//! measure variants side by side. Tests iterate [`supported`] and pass
//! kernels explicitly (`gemm_with`, `PackedA::pack_with`, …) instead of
//! touching the process-global override.
//!
//! Safety: all `unsafe` (intrinsics + raw-pointer panel walks) is
//! confined to the per-ISA submodules behind safe wrappers that assert
//! the packed-slice bounds first; a SIMD kernel is only ever reachable
//! through the dispatch table after its CPU features were detected at
//! runtime. Within one variant results are bit-identical run to run
//! (fixed reduction order, no threading here); *across* variants results
//! differ only by float rounding (FMA contracts mul+add into one
//! rounding step), which is why cross-ISA checks use tolerances while
//! per-ISA determinism checks use exact equality.

use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::OnceLock;

use super::Tensor;

// The per-ISA modules are private: a SIMD `Kernel` must only be
// reachable through [`selected`]/[`supported`]/[`by_name`], which gate
// it behind runtime feature detection — exposing e.g. `avx2::KERNEL`
// directly would let safe code run AVX2 intrinsics on a CPU without
// them (the wrappers also `debug_assert!` the features as a test-build
// backstop).
mod scalar;

#[cfg(target_arch = "x86_64")]
mod avx2;

#[cfg(target_arch = "aarch64")]
mod neon;

/// Epilogue fused into the last k-block writeback of the GEMM (and into
/// the matvec tail): per-row bias, then optional ReLU.
#[derive(Debug, Clone, Copy, Default)]
pub struct Epilogue<'a> {
    /// Per-output-row (= output-channel) bias, length `m`.
    pub bias: Option<&'a [f32]>,
    /// Apply `max(0, ·)` to the final values.
    pub relu: bool,
}

/// Epilogue of the int8 tier, fused into the i32→f32 dequantizing
/// writeback: `y = acc · (w_scale[row] · x_scale) (+ bias[row]) (→
/// ReLU)`. `scales` carries the *combined* per-output-row factor; bias
/// and ReLU are applied in f32, exactly as the f32 tier's [`Epilogue`].
#[derive(Debug, Clone, Copy)]
pub struct EpilogueI8<'a> {
    /// Combined dequant factor per output row, length `m`.
    pub scales: &'a [f32],
    /// Per-output-row bias (f32), length `m`; `None` on IC partials.
    pub bias: Option<&'a [f32]>,
    pub relu: bool,
}

/// Instruction-set family of a microkernel variant.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Isa {
    /// Portable fixed-array tile (autovectorized by LLVM).
    Scalar,
    /// x86-64 AVX2 + FMA intrinsics.
    Avx2,
    /// aarch64 NEON intrinsics.
    Neon,
}

impl Isa {
    pub fn name(self) -> &'static str {
        match self {
            Isa::Scalar => "scalar",
            Isa::Avx2 => "avx2",
            Isa::Neon => "neon",
        }
    }

    /// Code used by the [`force`] override slot (0 = no override).
    fn code(self) -> u8 {
        match self {
            Isa::Scalar => 1,
            Isa::Avx2 => 2,
            Isa::Neon => 3,
        }
    }
}

/// Register-tile microkernel: `c[row0.., col0..] += ap · bp` over packed
/// `kc×mr` / `kc×nr` panels, with the optional epilogue fused into the
/// writeback. `rows`/`cols` trim the ragged output edge (the panels
/// themselves are always full-width, zero-padded by the packers).
type TileFn = for<'a> fn(
    ap: &[f32],
    bp: &[f32],
    kc: usize,
    c: &mut [f32],
    n: usize,
    row0: usize,
    col0: usize,
    rows: usize,
    cols: usize,
    ep: Option<Epilogue<'a>>,
);

/// Dense-layer rows: `y[r] = w[r]·x (+ bias[r]) (→ ReLU)` for every row
/// of `w` (`y.len()` rows of length `k`). `k >= 1` (the caller handles
/// the degenerate `k = 0`).
type MatvecFn = for<'a> fn(
    w: &[f32],
    x: &[f32],
    bias: Option<&'a [f32]>,
    relu: bool,
    y: &mut [f32],
    k: usize,
);

/// Elementwise map over equal-length slices.
type MapFn = fn(src: &[f32], dst: &mut [f32]);

/// Int8 register tile over *k-pair interleaved* packed panels (see
/// `tensor::qgemm` for the layout): accumulate `ap · bp` into the `i32`
/// accumulator matrix `acc` at `(row0, col0)`; when `ep` is given (last
/// k-block) additionally dequantize `acc + partial` into the f32 output
/// `out` (same `n`-stride indexing as `acc`). All arithmetic is exact
/// integer math — every ISA produces bit-identical `i32` accumulators.
type TileFnI8 = for<'a> fn(
    ap: &[i8],
    bp: &[i8],
    kc: usize,
    acc: &mut [i32],
    out: &mut [f32],
    n: usize,
    row0: usize,
    col0: usize,
    rows: usize,
    cols: usize,
    ep: Option<EpilogueI8<'a>>,
);

/// Int8 dense rows: exact `i32` dot of row-major i8 `w` rows against i8
/// `x`, dequantized through the epilogue into f32 `y`. `k >= 1`.
type MatvecFnI8 = for<'a> fn(w: &[i8], x: &[i8], ep: EpilogueI8<'a>, y: &mut [f32], k: usize);

/// One microkernel variant: its tile geometry plus every ISA-specific
/// entry point the hot path dispatches through. Instances are `'static`
/// (one per compiled-in variant); all state is immutable.
#[derive(Debug)]
pub struct Kernel {
    pub isa: Isa,
    /// Tile height: rows of A/C per register tile (A panels are packed
    /// `mr`-tall).
    pub mr: usize,
    /// Tile width: columns of B/C per register tile (B panels are packed
    /// `nr`-wide).
    pub nr: usize,
    tile_fn: TileFn,
    matvec_fn: MatvecFn,
    relu_fn: MapFn,
    max_fn: MapFn,
}

impl Kernel {
    pub fn name(&self) -> &'static str {
        self.isa.name()
    }

    /// Human-readable ISA + tile geometry, e.g. `avx2 6x16` — printed by
    /// `iop exec`/`iop serve`/`cargo bench` so reported numbers are
    /// attributable to a code path.
    pub fn describe(&self) -> String {
        format!("{} {}x{}", self.name(), self.mr, self.nr)
    }

    /// Run the register tile (see [`TileFn`]).
    #[allow(clippy::too_many_arguments)]
    #[inline]
    pub fn tile(
        &self,
        ap: &[f32],
        bp: &[f32],
        kc: usize,
        c: &mut [f32],
        n: usize,
        row0: usize,
        col0: usize,
        rows: usize,
        cols: usize,
        ep: Option<Epilogue>,
    ) {
        (self.tile_fn)(ap, bp, kc, c, n, row0, col0, rows, cols, ep)
    }

    /// Dense rows `y = W·x (+bias)(→ReLU)`, `k >= 1` (see [`MatvecFn`]).
    #[inline]
    pub fn matvec_rows(
        &self,
        w: &[f32],
        x: &[f32],
        bias: Option<&[f32]>,
        relu: bool,
        y: &mut [f32],
        k: usize,
    ) {
        (self.matvec_fn)(w, x, bias, relu, y, k)
    }

    /// `dst = max(src, 0)` elementwise; lengths must match.
    #[inline]
    pub fn relu_map(&self, src: &[f32], dst: &mut [f32]) {
        assert_eq!(src.len(), dst.len(), "relu_map: length mismatch");
        (self.relu_fn)(src, dst)
    }

    /// `dst = max(dst, src)` elementwise; lengths must match. The fast
    /// maxpool's vertical (stride-1, contiguous) reduction.
    #[inline]
    pub fn max_into(&self, src: &[f32], dst: &mut [f32]) {
        assert_eq!(src.len(), dst.len(), "max_into: length mismatch");
        (self.max_fn)(src, dst)
    }
}

/// One int8 microkernel variant. Geometry is shared across all ISAs
/// (`mr = 4`, `nr = 16`, k-pair interleaved panels) so quantized
/// `PackedA` panels are ISA-portable and the per-ISA parity tests can
/// demand *bit-identical* `i32` accumulators, not just close floats —
/// int8 arithmetic is exact, so there is no FMA-style rounding excuse.
#[derive(Debug)]
pub struct KernelI8 {
    pub isa: Isa,
    /// Tile height (rows of A/C per register tile).
    pub mr: usize,
    /// Tile width (columns of B/C per register tile).
    pub nr: usize,
    tile_fn: TileFnI8,
    matvec_fn: MatvecFnI8,
}

impl KernelI8 {
    /// ISA tag of the int8 variant, e.g. `avx2-i8` — distinct from the
    /// f32 names so reports attribute numbers to the right tier.
    pub fn name(&self) -> &'static str {
        match self.isa {
            Isa::Scalar => "scalar-i8",
            Isa::Avx2 => "avx2-i8",
            Isa::Neon => "neon-i8",
        }
    }

    /// Human-readable tag + tile geometry, e.g. `avx2-i8 4x16`.
    pub fn describe(&self) -> String {
        format!("{} {}x{}", self.name(), self.mr, self.nr)
    }

    /// Run the int8 register tile (see [`TileFnI8`]).
    #[allow(clippy::too_many_arguments)]
    #[inline]
    pub fn tile(
        &self,
        ap: &[i8],
        bp: &[i8],
        kc: usize,
        acc: &mut [i32],
        out: &mut [f32],
        n: usize,
        row0: usize,
        col0: usize,
        rows: usize,
        cols: usize,
        ep: Option<EpilogueI8>,
    ) {
        (self.tile_fn)(ap, bp, kc, acc, out, n, row0, col0, rows, cols, ep)
    }

    /// Dense rows `y = dequant(W·x)`, `k >= 1` (see [`MatvecFnI8`]).
    #[inline]
    pub fn matvec_rows(&self, w: &[i8], x: &[i8], ep: EpilogueI8, y: &mut [f32], k: usize) {
        (self.matvec_fn)(w, x, ep, y, k)
    }
}

/// Process-global override slot for [`selected`]: 0 = auto-detect,
/// otherwise an [`Isa::code`]. Written only by [`force`] (in-process
/// benches) — the `IOP_KERNEL` env override lives in [`auto`] instead so
/// it is read exactly once.
static FORCED: AtomicU8 = AtomicU8::new(0);

/// The microkernel every dispatched GEMM/matvec/elementwise call routes
/// through: the [`force`] override if set, else the `IOP_KERNEL` env
/// override, else the widest ISA the CPU supports.
pub fn selected() -> &'static Kernel {
    match FORCED.load(Ordering::Relaxed) {
        1 => &scalar::KERNEL,
        #[cfg(target_arch = "x86_64")]
        2 => &avx2::KERNEL,
        #[cfg(target_arch = "aarch64")]
        3 => &neon::KERNEL,
        _ => auto(),
    }
}

/// Force a specific variant (`None` restores auto-detection). Meant for
/// single-threaded bench/CLI setup code that measures variants side by
/// side — sessions compile/pack against the kernel selected at creation
/// time, so flip this only between sessions. Only kernels obtained from
/// [`supported`]/[`by_name`] exist, so a forced kernel is always runnable
/// on this CPU. Tests should prefer the explicit `*_with` entry points,
/// which do not touch process-global state.
pub fn force(kern: Option<&'static Kernel>) {
    FORCED.store(kern.map_or(0, |k| k.isa.code()), Ordering::Relaxed);
}

/// Auto selection, memoized: `IOP_KERNEL` env override or detection.
fn auto() -> &'static Kernel {
    static AUTO: OnceLock<&'static Kernel> = OnceLock::new();
    AUTO.get_or_init(|| {
        if let Ok(name) = std::env::var("IOP_KERNEL") {
            return by_name(&name).unwrap_or_else(|| {
                panic!(
                    "IOP_KERNEL={name}: unknown or unsupported on this CPU \
                     (supported: {})",
                    supported_names().join(", ")
                )
            });
        }
        detect()
    })
}

/// Widest compiled-in variant this CPU can run.
fn detect() -> &'static Kernel {
    #[cfg(target_arch = "x86_64")]
    if is_x86_feature_detected!("avx2") && is_x86_feature_detected!("fma") {
        return &avx2::KERNEL;
    }
    #[cfg(target_arch = "aarch64")]
    if std::arch::is_aarch64_feature_detected!("neon") {
        return &neon::KERNEL;
    }
    &scalar::KERNEL
}

/// Every variant this binary can run on this CPU (scalar always; the
/// SIMD variant when detected). The ISA-parity tests sweep this list so
/// each compiled-in kernel is checked against the Reference oracle, not
/// just the auto-selected one.
pub fn supported() -> Vec<&'static Kernel> {
    let mut ks: Vec<&'static Kernel> = vec![&scalar::KERNEL];
    #[cfg(target_arch = "x86_64")]
    if is_x86_feature_detected!("avx2") && is_x86_feature_detected!("fma") {
        ks.push(&avx2::KERNEL);
    }
    #[cfg(target_arch = "aarch64")]
    if std::arch::is_aarch64_feature_detected!("neon") {
        ks.push(&neon::KERNEL);
    }
    ks
}

fn supported_names() -> Vec<&'static str> {
    supported().iter().map(|k| k.name()).collect()
}

/// Look up a *supported* variant by ISA name (`scalar|avx2|neon`).
pub fn by_name(name: &str) -> Option<&'static Kernel> {
    supported().into_iter().find(|k| k.name() == name)
}

/// The int8 twin of an ISA family. Every f32 variant has an i8 sibling
/// in the same submodule, so the mapping is total; the scalar fallback
/// arm is unreachable in practice (only supported ISAs are dispatched)
/// but keeps the match exhaustive on every target.
fn i8_for(isa: Isa) -> &'static KernelI8 {
    #[cfg(target_arch = "x86_64")]
    if isa == Isa::Avx2 {
        return &avx2::KERNEL_I8;
    }
    #[cfg(target_arch = "aarch64")]
    if isa == Isa::Neon {
        return &neon::KERNEL_I8;
    }
    let _ = isa;
    &scalar::KERNEL_I8
}

/// The int8 microkernel for the current session: follows the same
/// [`force`]/`IOP_KERNEL`/auto-detect resolution as [`selected`] — one
/// override knob steers both tiers, so a forced-scalar bench twin forces
/// scalar-i8 too.
pub fn selected_i8() -> &'static KernelI8 {
    i8_for(selected().isa)
}

/// Every int8 variant this binary can run on this CPU (mirrors
/// [`supported`]). The quantized parity tests sweep this list asserting
/// bit-identical i32 accumulators across variants.
pub fn supported_i8() -> Vec<&'static KernelI8> {
    supported().into_iter().map(|k| i8_for(k.isa)).collect()
}

/// Look up a *supported* int8 variant by tag (`scalar-i8|avx2-i8|neon-i8`).
pub fn by_name_i8(name: &str) -> Option<&'static KernelI8> {
    supported_i8().into_iter().find(|k| k.name() == name)
}

/// Shared ragged-edge writeback: `tile` is a row-major `rows×nr` (at
/// least) register-tile spill; add it into `c` at `(row0, col0)`,
/// trimmed to `rows×cols`, applying the epilogue if given. SIMD kernels
/// call this for partial tiles (full tiles stay vectorized end to end);
/// the scalar kernel uses it for every tile — it *is* the scalar
/// writeback.
#[allow(clippy::too_many_arguments)]
pub(crate) fn write_tile_edge(
    tile: &[f32],
    nr: usize,
    c: &mut [f32],
    n: usize,
    row0: usize,
    col0: usize,
    rows: usize,
    cols: usize,
    ep: Option<Epilogue>,
) {
    match ep {
        None => {
            for r in 0..rows {
                let base = (row0 + r) * n + col0;
                let acc = &tile[r * nr..r * nr + cols];
                for (dst, &v) in c[base..base + cols].iter_mut().zip(acc) {
                    *dst += v;
                }
            }
        }
        Some(ep) => {
            for r in 0..rows {
                let row = row0 + r;
                let base = row * n + col0;
                let bias = ep.bias.map_or(0.0, |b| b[row]);
                let acc = &tile[r * nr..r * nr + cols];
                for (dst, &v) in c[base..base + cols].iter_mut().zip(acc) {
                    let x = *dst + v + bias;
                    *dst = if ep.relu { x.max(0.0) } else { x };
                }
            }
        }
    }
}

/// Int8 ragged-edge writeback shared across ISAs: `tile` is a row-major
/// `rows×nr` (at least) i32 register-tile spill. Without an epilogue,
/// add it into `acc` at `(row0, col0)` trimmed to `rows×cols`; with one
/// (last k-block), dequantize `acc + tile` straight into the f32 `out`
/// instead — `acc` is dead after the final k-block, so it is not written
/// back.
#[allow(clippy::too_many_arguments)]
pub(crate) fn write_tile_edge_i8(
    tile: &[i32],
    nr: usize,
    acc: &mut [i32],
    out: &mut [f32],
    n: usize,
    row0: usize,
    col0: usize,
    rows: usize,
    cols: usize,
    ep: Option<EpilogueI8>,
) {
    match ep {
        None => {
            for r in 0..rows {
                let base = (row0 + r) * n + col0;
                let t = &tile[r * nr..r * nr + cols];
                for (dst, &v) in acc[base..base + cols].iter_mut().zip(t) {
                    *dst += v;
                }
            }
        }
        Some(ep) => {
            for r in 0..rows {
                let row = row0 + r;
                let base = row * n + col0;
                let scale = ep.scales[row];
                let bias = ep.bias.map_or(0.0, |b| b[row]);
                let t = &tile[r * nr..r * nr + cols];
                for (j, &v) in t.iter().enumerate() {
                    let total = acc[base + j] + v;
                    let x = total as f32 * scale + bias;
                    out[base + j] = if ep.relu { x.max(0.0) } else { x };
                }
            }
        }
    }
}

/// Elementwise ReLU on the dispatched kernel (the Fast/Compiled
/// backends' path; the Reference oracle keeps `ops::relu`). Exact — no
/// rounding is involved — so both backends agree bitwise.
pub fn relu(input: &Tensor) -> Tensor {
    relu_with(selected(), input)
}

/// [`relu`] on an explicit kernel variant (parity tests).
pub fn relu_with(kern: &Kernel, input: &Tensor) -> Tensor {
    let mut data = vec![0.0f32; input.len()];
    kern.relu_map(&input.data, &mut data);
    Tensor {
        c: input.c,
        h: input.h,
        w: input.w,
        data,
    }
}

/// Max pooling on the dispatched kernel — same contract as
/// `ops::maxpool2d` (square window `k`, stride `s`, no padding).
///
/// Decomposed into a vertical pass and a horizontal pass: the vertical
/// window max runs over *contiguous* input rows (`Kernel::max_into`, a
/// stride-1 SIMD max), then the horizontal reduce reads `k` adjacent
/// entries of the row buffer per output. `max` is exact and
/// order-independent, so the result is bit-identical to the reference
/// loop nest.
pub fn maxpool2d(input: &Tensor, k: usize, stride: usize) -> Tensor {
    maxpool2d_with(selected(), input, k, stride)
}

/// [`maxpool2d`] on an explicit kernel variant (parity tests).
pub fn maxpool2d_with(kern: &Kernel, input: &Tensor, k: usize, stride: usize) -> Tensor {
    assert!(k >= 1 && stride >= 1);
    assert!(
        input.h >= k && input.w >= k,
        "maxpool2d: window {}x{} exceeds input {}x{}x{}",
        k,
        k,
        input.c,
        input.h,
        input.w
    );
    let out_h = (input.h - k) / stride + 1;
    let out_w = (input.w - k) / stride + 1;
    let mut out = Tensor::zeros(input.c, out_h, out_w);
    let mut rowmax = vec![0.0f32; input.w];
    for c in 0..input.c {
        for oy in 0..out_h {
            let iy0 = oy * stride;
            let row0 = input.idx(c, iy0, 0);
            rowmax.copy_from_slice(&input.data[row0..row0 + input.w]);
            for ky in 1..k {
                let row = input.idx(c, iy0 + ky, 0);
                kern.max_into(&input.data[row..row + input.w], &mut rowmax);
            }
            let out_base = out.idx(c, oy, 0);
            for ox in 0..out_w {
                let x0 = ox * stride;
                let mut m = rowmax[x0];
                for &v in &rowmax[x0 + 1..x0 + k] {
                    m = m.max(v);
                }
                out.data[out_base + ox] = m;
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::ops;
    use crate::util::prng::SplitMix64;

    fn rand_tensor(c: usize, h: usize, w: usize, seed: u64) -> Tensor {
        let mut r = SplitMix64::new(seed);
        let data = (0..c * h * w).map(|_| r.next_symmetric(1.0)).collect();
        Tensor::from_vec(c, h, w, data)
    }

    #[test]
    fn selection_is_supported_and_stable() {
        let sel = selected();
        assert!(
            supported().iter().any(|k| std::ptr::eq(*k, sel)),
            "selected kernel must be in the supported set"
        );
        // Memoized: repeated calls return the same kernel.
        assert!(std::ptr::eq(selected(), sel));
        // Scalar is always compiled in and resolvable by name.
        let sc = by_name("scalar").expect("scalar always supported");
        assert_eq!(sc.isa, Isa::Scalar);
        assert_eq!(sc.describe(), format!("scalar {}x{}", sc.mr, sc.nr));
        assert!(by_name("no-such-isa").is_none());
    }

    #[test]
    fn tile_geometry_is_sane() {
        for kern in supported() {
            assert!(kern.mr >= 1 && kern.nr >= 1, "{}", kern.name());
            // The packers and `gemm`'s row-block rounding rely on tiles
            // no taller/wider than the cache blocks they subdivide.
            assert!(kern.mr <= 16 && kern.nr <= 64, "{}", kern.name());
        }
    }

    #[test]
    fn every_variant_relu_matches_reference_bitwise() {
        let t = rand_tensor(3, 7, 11, 42);
        let want = ops::relu(&t);
        for kern in supported() {
            let got = relu_with(kern, &t);
            assert_eq!(got, want, "{} relu diverged", kern.name());
        }
    }

    #[test]
    fn every_variant_maxpool_matches_reference_bitwise() {
        // Window/stride combos covering tiling edges and stride<k overlap.
        let cases = [
            (2usize, 2usize, 8usize, 8usize),
            (3, 2, 9, 11),
            (2, 1, 5, 6),
            (1, 1, 4, 4),
        ];
        for (i, &(k, s, h, w)) in cases.iter().enumerate() {
            let t = rand_tensor(2, h, w, 100 + i as u64);
            let want = ops::maxpool2d(&t, k, s);
            for kern in supported() {
                let got = maxpool2d_with(kern, &t, k, s);
                assert_eq!(got, want, "{} maxpool k={k} s={s} diverged", kern.name());
            }
        }
    }

    #[test]
    fn write_tile_edge_trims_and_applies_epilogue() {
        // 2x3 tile (nr = 4 stride) into a 3x5 C at (1, 2), bias + relu.
        let tile = vec![
            1.0, -2.0, 3.0, 99.0, // row 0 (col 3 ignored: cols = 3)
            -4.0, 5.0, -6.0, 99.0, // row 1
        ];
        let mut c = vec![0.5f32; 3 * 5];
        let bias = vec![0.0, -1.0, 1.0];
        let ep = Epilogue {
            bias: Some(&bias),
            relu: true,
        };
        write_tile_edge(&tile, 4, &mut c, 5, 1, 2, 2, 3, Some(ep));
        // Row 1 (bias -1): max(0, 0.5 + v - 1).
        assert_eq!(&c[7..10], &[0.5, 0.0, 2.5]);
        // Row 2 (bias +1): max(0, 0.5 + v + 1).
        assert_eq!(&c[12..15], &[0.0, 6.5, 0.0]);
        // Untouched cells keep the seed value.
        assert_eq!(c[0], 0.5);
        assert_eq!(c[6], 0.5);
    }

    fn rand_i8(len: usize, seed: u64) -> Vec<i8> {
        let mut r = SplitMix64::new(seed);
        (0..len)
            .map(|_| (r.next_symmetric(127.0) as i32).clamp(-127, 127) as i8)
            .collect()
    }

    /// Pack row-major `mr×kc` A and `kc×nr` B into the k-pair interleaved
    /// panel layout the i8 tiles expect (odd `kc` zero-padded).
    fn pack_pairs(
        a: &[i8],
        b: &[i8],
        mr: usize,
        nr: usize,
        kc: usize,
    ) -> (Vec<i8>, Vec<i8>) {
        let kp = kc.div_ceil(2);
        let mut ap = vec![0i8; kp * mr * 2];
        let mut bp = vec![0i8; kp * nr * 2];
        for p2 in 0..kp {
            for r in 0..mr {
                ap[(p2 * mr + r) * 2] = a[r * kc + 2 * p2];
                if 2 * p2 + 1 < kc {
                    ap[(p2 * mr + r) * 2 + 1] = a[r * kc + 2 * p2 + 1];
                }
            }
            for j in 0..nr {
                bp[(p2 * nr + j) * 2] = b[2 * p2 * nr + j];
                if 2 * p2 + 1 < kc {
                    bp[(p2 * nr + j) * 2 + 1] = b[(2 * p2 + 1) * nr + j];
                }
            }
        }
        (ap, bp)
    }

    #[test]
    fn i8_selection_mirrors_f32_dispatch() {
        assert_eq!(selected_i8().isa, selected().isa);
        assert_eq!(supported_i8().len(), supported().len());
        let sc = by_name_i8("scalar-i8").expect("scalar-i8 always supported");
        assert_eq!(sc.describe(), format!("scalar-i8 {}x{}", sc.mr, sc.nr));
        assert!(by_name_i8("scalar").is_none());
        // Shared geometry: quantized panels are ISA-portable.
        for k in supported_i8() {
            assert_eq!((k.mr, k.nr), (4, 16), "{}", k.name());
        }
    }

    #[test]
    fn every_i8_variant_tile_bit_identical_accumulators() {
        // Odd kc exercises the zero-padded trailing pair; the ragged
        // (rows=3, cols=11) call exercises the edge writeback.
        let (mr, nr, kc) = (4usize, 16usize, 37usize);
        let a = rand_i8(mr * kc, 7);
        let b = rand_i8(kc * nr, 8);
        let (ap, bp) = pack_pairs(&a, &b, mr, nr, kc);
        // Exact integer reference.
        let mut want = vec![0i32; mr * nr];
        for r in 0..mr {
            for j in 0..nr {
                for p in 0..kc {
                    want[r * nr + j] += a[r * kc + p] as i32 * b[p * nr + j] as i32;
                }
            }
        }
        let scales: Vec<f32> = (0..mr).map(|r| 0.01 + r as f32 * 0.003).collect();
        let bias: Vec<f32> = (0..mr).map(|r| r as f32 * 0.25 - 0.3).collect();
        for kern in supported_i8() {
            // Full tile, no epilogue: accumulators must match exactly.
            let mut acc = vec![0i32; mr * nr];
            let mut out = vec![0.0f32; mr * nr];
            kern.tile(&ap, &bp, kc, &mut acc, &mut out, nr, 0, 0, mr, nr, None);
            assert_eq!(acc, want, "{} full-tile acc diverged", kern.name());
            // Ragged tile with dequant epilogue: f32 out is exact too
            // (same scalar dequant expression on identical i32 totals).
            let ep = EpilogueI8 {
                scales: &scales,
                bias: Some(&bias),
                relu: true,
            };
            let mut acc2 = vec![0i32; mr * nr];
            let mut out2 = vec![0.0f32; mr * nr];
            kern.tile(&ap, &bp, kc, &mut acc2, &mut out2, nr, 0, 0, 3, 11, Some(ep));
            for r in 0..3 {
                for j in 0..11 {
                    let x = want[r * nr + j] as f32 * scales[r] + bias[r];
                    assert_eq!(
                        out2[r * nr + j],
                        x.max(0.0),
                        "{} ragged dequant ({r},{j})",
                        kern.name()
                    );
                }
            }
            // Full tile with epilogue: the vectorized dequant path must
            // match the scalar expression exactly (unfused mul + add on
            // identical i32 totals — no rounding freedom).
            let mut acc3 = vec![0i32; mr * nr];
            let mut out3 = vec![0.0f32; mr * nr];
            kern.tile(&ap, &bp, kc, &mut acc3, &mut out3, nr, 0, 0, mr, nr, Some(ep));
            for r in 0..mr {
                for j in 0..nr {
                    let x = want[r * nr + j] as f32 * scales[r] + bias[r];
                    assert_eq!(
                        out3[r * nr + j],
                        x.max(0.0),
                        "{} full dequant ({r},{j})",
                        kern.name()
                    );
                }
            }
        }
    }

    #[test]
    fn every_i8_variant_matvec_bit_identical() {
        let (m, k) = (5usize, 83usize); // odd k exercises SIMD tails
        let w = rand_i8(m * k, 21);
        let x = rand_i8(k, 22);
        let scales: Vec<f32> = (0..m).map(|r| 0.02 + r as f32 * 0.001).collect();
        let bias: Vec<f32> = (0..m).map(|r| 0.1 - r as f32 * 0.05).collect();
        let mut want = vec![0.0f32; m];
        for r in 0..m {
            let mut acc = 0i32;
            for i in 0..k {
                acc += w[r * k + i] as i32 * x[i] as i32;
            }
            want[r] = (acc as f32 * scales[r] + bias[r]).max(0.0);
        }
        for kern in supported_i8() {
            let ep = EpilogueI8 {
                scales: &scales,
                bias: Some(&bias),
                relu: true,
            };
            let mut y = vec![0.0f32; m];
            kern.matvec_rows(&w, &x, ep, &mut y, k);
            assert_eq!(y, want, "{} matvec diverged", kern.name());
        }
    }

    #[test]
    fn write_tile_edge_i8_accumulates_then_dequantizes() {
        let nr = 4usize;
        let tile = vec![10i32, -20, 30, 99, -40, 50, -60, 99];
        let mut acc = vec![5i32; 3 * 5];
        let mut out = vec![0.0f32; 3 * 5];
        // No epilogue: adds into acc, leaves out untouched.
        write_tile_edge_i8(&tile, nr, &mut acc, &mut out, 5, 1, 2, 2, 3, None);
        assert_eq!(&acc[7..10], &[15, -15, 35]);
        assert_eq!(&acc[12..15], &[-35, 55, -55]);
        assert_eq!(acc[0], 5);
        assert!(out.iter().all(|&v| v == 0.0));
        // Epilogue: dequantizes acc + tile into out (acc already holds
        // the earlier partial, so pass the same tile again). Scales are
        // powers of two so the expected values are exact in f32.
        let scales = vec![1.0f32, 0.5, 0.25];
        let bias = vec![0.0f32, 1.0, -1.0];
        let ep = EpilogueI8 {
            scales: &scales,
            bias: Some(&bias),
            relu: false,
        };
        write_tile_edge_i8(&tile, nr, &mut acc, &mut out, 5, 1, 2, 2, 3, Some(ep));
        // Row 1 (scale 0.5, bias 1.0): (acc + tile) * 0.5 + 1.
        assert_eq!(&out[7..10], &[13.5, -16.5, 33.5]);
        // Row 2 (scale 0.25, bias -1.0).
        assert_eq!(&out[12..15], &[-19.75, 25.25, -29.75]);
    }
}
