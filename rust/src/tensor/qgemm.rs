//! Int8 counterpart of `tensor::gemm`: cache-blocked i8×i8→i32 GEMM
//! with the dequantizing bias+ReLU epilogue fused into the i32→f32
//! writeback.
//!
//! Same BLIS-style structure as the f32 path — `NC`-wide column panels,
//! `KC`-deep k blocks, `mr`-tall prepacked A row panels, per-thread
//! grow-only B-pack scratch — with two int8-specific twists:
//!
//!  * **k-pair interleaved panels.** The i8 microkernels consume the
//!    reduction axis two taps at a time (`vpmaddwd` / widening-add pair
//!    sums), so panels store byte *pairs*: A keeps `(a[r][p], a[r][p+1])`
//!    at offset `((p2*mr)+r)*2` and B keeps `(b[p][j], b[p+1][j])` at
//!    `((p2*nr)+j)*2`, where `p2 = p/2` is local to the k block. Odd
//!    `kc` pads the trailing pair with zero — exact under integer math.
//!  * **overwrite, not accumulate.** The f32 GEMM accumulates into C;
//!    here the i32 accumulator matrix lives in scratch and the final
//!    k block dequantizes it straight into the f32 output
//!    (`out = acc · scale (+ bias) (→ ReLU)`), so `c` is overwritten.
//!    Partial products across k blocks still accumulate — in i32, which
//!    is exact: every ISA variant produces bit-identical accumulators
//!    *and* (because the dequant expression is fixed and unfused)
//!    bit-identical f32 outputs.
//!
//! Quantization scheme (see `tensor::quant`): symmetric per-output-
//! channel weight scales, symmetric per-tensor activation scale,
//! zero-point 0 everywhere — conv zero padding quantizes to exactly 0,
//! so the virtual [`QIm2colView`] pads with the same byte the f32 view
//! pads with.

use super::gemm::{BPanelProvider, KC, NC};
use super::im2col::Im2colView;
use super::kernels::{self, EpilogueI8, KernelI8};
use super::quant;
use super::Tensor;

/// Row-block height cap, rounded down to the i8 tile's `mr` multiple
/// (mirrors `gemm::MC`).
const MC: usize = 64;

fn row_block(kern: &KernelI8) -> usize {
    (MC / kern.mr).max(1) * kern.mr
}

/// An `m×k` f32 matrix quantized to symmetric per-row int8 and packed
/// into the i8 GEMM's k-pair interleaved, `mr`-tall row-panel layout,
/// blocked `(k block, row block)` exactly like `gemm::PackedA`. The
/// per-row weight scales ride alongside the panels; the packing kernel
/// is recorded so panels and the consuming microkernel always agree.
#[derive(Debug, Clone)]
pub struct PackedAI8 {
    /// Rows of the original matrix (output channels).
    pub m: usize,
    /// Columns of the original matrix (reduction depth).
    pub k: usize,
    data: Vec<i8>,
    /// Per-row symmetric weight scales (`quant::quantize_rows`).
    scales: Vec<f32>,
    /// Start of each `(k block, row block)` group in `data`, k-block-major.
    offsets: Vec<usize>,
    n_row_blocks: usize,
    rb: usize,
    kernel: &'static KernelI8,
}

impl PackedAI8 {
    /// Quantize + pack for the selected i8 kernel, row-blocked so at
    /// least `threads` row blocks exist whenever `m` allows it.
    pub fn pack_for_threads(m: usize, k: usize, a: &[f32], threads: usize) -> PackedAI8 {
        Self::pack_with(kernels::selected_i8(), m, k, a, threads)
    }

    /// [`PackedAI8::pack_for_threads`] against an explicit i8 kernel
    /// variant (ISA-parity tests / side-by-side benches).
    pub fn pack_with(
        kern: &'static KernelI8,
        m: usize,
        k: usize,
        a: &[f32],
        threads: usize,
    ) -> PackedAI8 {
        assert_eq!(a.len(), m * k, "qpack: A must be m*k");
        let (q, scales) = quant::quantize_rows(a, m, k);
        let mr = kern.mr;
        let rb = (m.div_ceil(threads.max(1)).div_ceil(mr) * mr).clamp(mr, row_block(kern));
        let n_row_blocks = m.div_ceil(rb);
        let mut data = Vec::new();
        let mut offsets = Vec::new();
        for pc in (0..k).step_by(KC) {
            let kc = KC.min(k - pc);
            let kp = kc.div_ceil(2);
            for ic in (0..m).step_by(rb) {
                let mc = rb.min(m - ic);
                let start = data.len();
                offsets.push(start);
                let n_tiles = mc.div_ceil(mr);
                data.resize(start + n_tiles * kp * mr * 2, 0);
                let block = &mut data[start..];
                for it in 0..n_tiles {
                    let i0 = ic + it * mr;
                    let rows = mr.min(ic + mc - i0);
                    let tile = &mut block[it * kp * mr * 2..(it + 1) * kp * mr * 2];
                    for p2 in 0..kp {
                        for r in 0..rows {
                            let base = (p2 * mr + r) * 2;
                            tile[base] = q[(i0 + r) * k + pc + 2 * p2];
                            if 2 * p2 + 1 < kc {
                                tile[base + 1] = q[(i0 + r) * k + pc + 2 * p2 + 1];
                            }
                        }
                    }
                }
            }
        }
        PackedAI8 {
            m,
            k,
            data,
            scales,
            offsets,
            n_row_blocks,
            rb,
            kernel: kern,
        }
    }

    /// Packed size in bytes: 1 byte per packed weight plus the f32
    /// per-row scales — the number deployment reports compare against
    /// the f32 `PackedA` footprint (≈ 4× shrink).
    pub fn bytes(&self) -> usize {
        self.data.len() + self.scales.len() * 4
    }

    /// Per-row symmetric weight scales.
    pub fn scales(&self) -> &[f32] {
        &self.scales
    }

    /// The i8 microkernel this matrix was packed for.
    pub fn kernel(&self) -> &'static KernelI8 {
        self.kernel
    }

    fn block(&self, pc_idx: usize, ic_idx: usize) -> &[i8] {
        let i = pc_idx * self.n_row_blocks + ic_idx;
        let start = self.offsets[i];
        let end = self.offsets.get(i + 1).copied().unwrap_or(self.data.len());
        &self.data[start..end]
    }
}

/// Grow-only scratch for the i8 prepacked GEMM: per-thread B-pack
/// buffers (i8, pair-interleaved) plus the shared i32 accumulator
/// matrix. Mirrors `gemm::PackScratch`'s contract — buffers are
/// retained across calls and [`QPackScratch::grow_count`] is flat once
/// warm, so the executor's no-alloc soak assertions extend to the
/// quantized tier unchanged.
#[derive(Debug, Default)]
pub struct QPackScratch {
    bufs: Vec<Vec<i8>>,
    acc: Vec<i32>,
    grows: u64,
}

impl QPackScratch {
    pub fn new() -> Self {
        Self::default()
    }

    /// Number of buffer growths since creation.
    pub fn grow_count(&self) -> u64 {
        self.grows
    }

    /// Scratch bytes currently held (pack buffers + i32 accumulator).
    /// Zero until the first i8 call — f32 sessions report unchanged
    /// peaks.
    pub fn bytes(&self) -> u64 {
        self.bufs.iter().map(|b| b.len() as u64).sum::<u64>() + self.acc.len() as u64 * 4
    }

    /// At least `t` pack buffers of `len` bytes and an accumulator of
    /// `acc_len` i32s, returned as disjoint borrows.
    fn parts(&mut self, t: usize, len: usize, acc_len: usize) -> (&mut [Vec<i8>], &mut [i32]) {
        if self.bufs.len() < t {
            self.bufs.resize_with(t, Vec::new);
            self.grows += 1;
        }
        for b in &mut self.bufs[..t] {
            if b.len() < len {
                b.resize(len, 0);
                self.grows += 1;
            }
        }
        if self.acc.len() < acc_len {
            self.acc.resize(acc_len, 0);
            self.grows += 1;
        }
        (&mut self.bufs[..t], &mut self.acc[..acc_len])
    }
}

/// Source of the i8 GEMM's B operand: packed `kc×nc` blocks in the
/// k-pair interleaved layout (`((p2*nr)+j)*2`; `p2` local to the k
/// block, odd `kc` zero-padded). Same role as `gemm::BPanelProvider` —
/// a materialized i8 matrix ([`DenseBI8`]) or the virtual quantized
/// im2col view ([`QIm2colView`]).
pub trait BPanelProviderI8: Sync {
    /// Rows of B (the reduction depth `k`).
    fn k(&self) -> usize;
    /// Columns of B (the output width `n`).
    fn n(&self) -> usize;
    /// Pack the `kc×nc` block at `(pc, jc)` into pair-interleaved
    /// `nr`-wide panels in `bpack` (panel `jt` occupies
    /// `bpack[jt*kp*nr*2..(jt+1)*kp*nr*2]`, `kp = kc.div_ceil(2)`).
    fn pack_panel(&self, bpack: &mut [i8], jc: usize, nc: usize, pc: usize, kc: usize, nr: usize);
}

/// The trivial provider: a materialized row-major `k×n` i8 matrix.
pub struct DenseBI8<'a> {
    k: usize,
    n: usize,
    b: &'a [i8],
}

impl<'a> DenseBI8<'a> {
    pub fn new(k: usize, n: usize, b: &'a [i8]) -> DenseBI8<'a> {
        assert_eq!(b.len(), k * n, "qgemm: B must be k*n");
        DenseBI8 { k, n, b }
    }
}

impl BPanelProviderI8 for DenseBI8<'_> {
    fn k(&self) -> usize {
        self.k
    }

    fn n(&self) -> usize {
        self.n
    }

    fn pack_panel(&self, bpack: &mut [i8], jc: usize, nc: usize, pc: usize, kc: usize, nr: usize) {
        let kp = kc.div_ceil(2);
        let n_panels = nc.div_ceil(nr);
        assert!(
            bpack.len() >= n_panels * kp * nr * 2,
            "qgemm pack_panel: scratch buffer too small"
        );
        for jt in 0..n_panels {
            let j0 = jc + jt * nr;
            let cols = nr.min(jc + nc - j0);
            let panel = &mut bpack[jt * kp * nr * 2..(jt + 1) * kp * nr * 2];
            for (p2, dst) in panel.chunks_exact_mut(nr * 2).enumerate() {
                let r0 = (pc + 2 * p2) * self.n + j0;
                let hi = 2 * p2 + 1 < kc;
                for j in 0..nr {
                    if j < cols {
                        dst[j * 2] = self.b[r0 + j];
                        dst[j * 2 + 1] = if hi { self.b[r0 + self.n + j] } else { 0 };
                    } else {
                        dst[j * 2] = 0;
                        dst[j * 2 + 1] = 0;
                    }
                }
            }
        }
    }
}

/// The implicit-GEMM conv provider of the int8 tier: a virtual im2col
/// matrix over a *pre-quantized* i8 stage input (the whole input is
/// quantized once per stage into an arena buffer; zero-point 0 means
/// conv padding gathers the literal 0 byte). Gathers two tap rows per
/// pair step through the same interior/border segment walk as
/// `im2col::Im2colView`, interleaving straight into the pair-format
/// panel — no i8 column matrix is ever materialized.
pub struct QIm2colView<'a> {
    data: &'a [i8],
    c: usize,
    h: usize,
    w: usize,
    k_h: usize,
    k_w: usize,
    stride: usize,
    pad_h: usize,
    pad_w: usize,
    out_h: usize,
    out_w: usize,
}

impl<'a> QIm2colView<'a> {
    /// `data` is the quantized input, CHW layout, `c*h*w` bytes.
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        data: &'a [i8],
        c: usize,
        h: usize,
        w: usize,
        k_h: usize,
        k_w: usize,
        stride: usize,
        pad_h: usize,
        pad_w: usize,
        out_h: usize,
        out_w: usize,
    ) -> QIm2colView<'a> {
        assert_eq!(data.len(), c * h * w, "qim2col: data must be c*h*w");
        assert!(stride >= 1, "qim2col: stride must be >= 1");
        assert_eq!(
            out_h,
            (h + 2 * pad_h - k_h) / stride + 1,
            "qim2col: out_h inconsistent with conv geometry"
        );
        assert_eq!(
            out_w,
            (w + 2 * pad_w - k_w) / stride + 1,
            "qim2col: out_w inconsistent with conv geometry"
        );
        QIm2colView {
            data,
            c,
            h,
            w,
            k_h,
            k_w,
            stride,
            pad_h,
            pad_w,
            out_h,
            out_w,
        }
    }

    /// Quantize `input` with `scale` into `buf` and view it (the conv
    /// serving path: `buf` is the arena's i8 stage-input buffer).
    #[allow(clippy::too_many_arguments)]
    pub fn quantize(
        input: &Tensor,
        scale: f32,
        buf: &'a mut [i8],
        k_h: usize,
        k_w: usize,
        stride: usize,
        pad_h: usize,
        pad_w: usize,
        out_h: usize,
        out_w: usize,
    ) -> QIm2colView<'a> {
        let used = &mut buf[..input.len()];
        quant::quantize_into(&input.data, scale, used);
        QIm2colView::new(
            used, input.c, input.h, input.w, k_h, k_w, stride, pad_h, pad_w, out_h, out_w,
        )
    }

    /// One tap row's bytes for `count` consecutive output pixels
    /// starting at flat output index `j0` — the i8 twin of
    /// `Im2colView::gather_tap_cols`, stepping `step` bytes between
    /// writes (2 to interleave directly into a pair panel).
    #[allow(clippy::too_many_arguments)]
    fn gather_tap_cols(
        &self,
        ic: usize,
        ky: usize,
        kx: usize,
        j0: usize,
        dst: &mut [i8],
        count: usize,
        step: usize,
    ) {
        let h = self.h as isize;
        let w = self.w as isize;
        let mut j = j0;
        let mut done = 0usize;
        while done < count {
            let oy = j / self.out_w;
            let ox0 = j % self.out_w;
            let seg = (self.out_w - ox0).min(count - done);
            let iy = (oy * self.stride + ky) as isize - self.pad_h as isize;
            if iy < 0 || iy >= h {
                for t in 0..seg {
                    dst[(done + t) * step] = 0;
                }
            } else {
                let src_row = (ic * self.h + iy as usize) * self.w;
                if self.stride == 1 {
                    let off = kx as isize - self.pad_w as isize;
                    let seg_end = (ox0 + seg) as isize;
                    let lo = (-off).clamp(ox0 as isize, seg_end) as usize;
                    let hi = (w - off).clamp(ox0 as isize, seg_end) as usize;
                    for t in 0..lo - ox0 {
                        dst[(done + t) * step] = 0;
                    }
                    let src0 = (src_row as isize + lo as isize + off) as usize;
                    for t in 0..hi - lo {
                        dst[(done + lo - ox0 + t) * step] = self.data[src0 + t];
                    }
                    for t in hi - ox0..seg {
                        dst[(done + t) * step] = 0;
                    }
                } else {
                    for t in 0..seg {
                        let ix = ((ox0 + t) * self.stride + kx) as isize - self.pad_w as isize;
                        dst[(done + t) * step] = if ix >= 0 && ix < w {
                            self.data[src_row + ix as usize]
                        } else {
                            0
                        };
                    }
                }
            }
            done += seg;
            j += seg;
        }
    }
}

impl BPanelProviderI8 for QIm2colView<'_> {
    fn k(&self) -> usize {
        self.c * self.k_h * self.k_w
    }

    fn n(&self) -> usize {
        self.out_h * self.out_w
    }

    fn pack_panel(&self, bpack: &mut [i8], jc: usize, nc: usize, pc: usize, kc: usize, nr: usize) {
        let kp = kc.div_ceil(2);
        let n_panels = nc.div_ceil(nr);
        assert!(
            bpack.len() >= n_panels * kp * nr * 2,
            "qim2col pack_panel: scratch buffer too small"
        );
        for jt in 0..n_panels {
            let j0 = jc + jt * nr;
            let cols = nr.min(jc + nc - j0);
            let panel = &mut bpack[jt * kp * nr * 2..(jt + 1) * kp * nr * 2];
            for (p2, dst) in panel.chunks_exact_mut(nr * 2).enumerate() {
                for j in cols..nr {
                    dst[j * 2] = 0;
                    dst[j * 2 + 1] = 0;
                }
                // Low byte of each pair: tap row pc + 2*p2.
                let row = pc + 2 * p2;
                let kx = row % self.k_w;
                let ky = (row / self.k_w) % self.k_h;
                let ic = row / (self.k_w * self.k_h);
                self.gather_tap_cols(ic, ky, kx, j0, dst, cols, 2);
                // High byte: tap row pc + 2*p2 + 1, zero-padded past kc.
                if 2 * p2 + 1 < kc {
                    let row = pc + 2 * p2 + 1;
                    let kx = row % self.k_w;
                    let ky = (row / self.k_w) % self.k_h;
                    let ic = row / (self.k_w * self.k_h);
                    self.gather_tap_cols(ic, ky, kx, j0, &mut dst[1..], cols, 2);
                } else {
                    for j in 0..cols {
                        dst[j * 2 + 1] = 0;
                    }
                }
            }
        }
    }
}

/// `c = dequant(pa · src)` — the i8 prepacked GEMM. `ep.scales` must
/// carry the *combined* per-row factor (`w_scale[row] · x_scale`,
/// length `m`); the output is overwritten, not accumulated (see module
/// docs). `threads > 1` row-splits at the pack-time row-block
/// granularity over `std::thread::scope`, exactly like the f32 path —
/// the i32 accumulator and f32 output split into the same disjoint row
/// slices.
pub fn gemm_i8_prepacked_from<S: BPanelProviderI8>(
    pa: &PackedAI8,
    src: &S,
    c: &mut [f32],
    ep: EpilogueI8,
    threads: usize,
    scratch: &mut QPackScratch,
) {
    let (m, k) = (pa.m, pa.k);
    let n = src.n();
    let kern = pa.kernel;
    assert_eq!(src.k(), k, "qgemm: provider depth must match packed A");
    assert_eq!(c.len(), m * n, "qgemm: C must be m*n");
    assert_eq!(ep.scales.len(), m, "qgemm: one scale per row");
    if let Some(bias) = ep.bias {
        assert_eq!(bias.len(), m, "qgemm: bias must have one entry per row");
    }
    if m == 0 || n == 0 {
        return;
    }
    if k == 0 {
        for (row, crow) in c.chunks_exact_mut(n).enumerate() {
            let bias = ep.bias.map_or(0.0, |b| b[row]);
            let v = if ep.relu { bias.max(0.0) } else { bias };
            crow.fill(v);
        }
        return;
    }
    let nr = kern.nr;
    let bpack_len = NC.min(n).div_ceil(nr) * nr * KC.min(k).div_ceil(2) * 2;
    let flops = 2.0 * m as f64 * n as f64 * k as f64;
    let t = if flops < 2e6 {
        1
    } else {
        threads.clamp(1, pa.n_row_blocks)
    };
    let (bufs, acc) = scratch.parts(t, bpack_len, m * n);
    acc.fill(0);
    if t == 1 {
        gemm_i8_rows(pa, 0, pa.n_row_blocks, src, c, acc, ep, &mut bufs[0]);
        return;
    }
    let base = pa.n_row_blocks / t;
    let extra = pa.n_row_blocks % t;
    std::thread::scope(|scope| {
        let mut c_rest = c;
        let mut a_rest = acc;
        let mut blk0 = 0usize;
        for (i, buf) in bufs.iter_mut().enumerate().take(t) {
            let n_blks = base + usize::from(i < extra);
            let row0 = blk0 * pa.rb;
            let rows = (n_blks * pa.rb).min(m - row0);
            let (c_blk, c_tail) = std::mem::take(&mut c_rest).split_at_mut(rows * n);
            c_rest = c_tail;
            let (a_blk, a_tail) = std::mem::take(&mut a_rest).split_at_mut(rows * n);
            a_rest = a_tail;
            let ep_blk = EpilogueI8 {
                scales: &ep.scales[row0..row0 + rows],
                bias: ep.bias.map(|bv| &bv[row0..row0 + rows]),
                relu: ep.relu,
            };
            let b0 = blk0;
            scope.spawn(move || {
                gemm_i8_rows(pa, b0, n_blks, src, c_blk, a_blk, ep_blk, buf);
            });
            blk0 += n_blks;
        }
    });
}

/// Serial i8 kernel over row blocks `[row_blk0, row_blk0+n_blks)`;
/// `c_blk`/`acc_blk` hold exactly those rows (epilogue slices are
/// row-block-local).
#[allow(clippy::too_many_arguments)]
fn gemm_i8_rows<S: BPanelProviderI8>(
    pa: &PackedAI8,
    row_blk0: usize,
    n_blks: usize,
    src: &S,
    c_blk: &mut [f32],
    acc_blk: &mut [i32],
    ep: EpilogueI8,
    bpack: &mut [i8],
) {
    let k = pa.k;
    let n = src.n();
    let kern = pa.kernel;
    let (mr, nr) = (kern.mr, kern.nr);
    for jc in (0..n).step_by(NC) {
        let nc = NC.min(n - jc);
        let n_panels = nc.div_ceil(nr);
        for (pc_idx, pc) in (0..k).step_by(KC).enumerate() {
            let kc = KC.min(k - pc);
            let kp = kc.div_ceil(2);
            let last_k = pc + kc == k;
            src.pack_panel(bpack, jc, nc, pc, kc, nr);
            for blk in 0..n_blks {
                let ic_global = (row_blk0 + blk) * pa.rb;
                let mc = pa.rb.min(pa.m - ic_global);
                let ap_block = pa.block(pc_idx, row_blk0 + blk);
                let local_base = blk * pa.rb;
                let n_tiles = mc.div_ceil(mr);
                for it in 0..n_tiles {
                    let i0 = it * mr;
                    let rows = mr.min(mc - i0);
                    let ap = &ap_block[it * kp * mr * 2..(it + 1) * kp * mr * 2];
                    for jt in 0..n_panels {
                        let j0 = jt * nr;
                        let cols = nr.min(nc - j0);
                        let bp = &bpack[jt * kp * nr * 2..(jt + 1) * kp * nr * 2];
                        let tile_ep = if last_k { Some(ep) } else { None };
                        kern.tile(
                            ap,
                            bp,
                            kc,
                            acc_blk,
                            c_blk,
                            n,
                            local_base + i0,
                            jc + j0,
                            rows,
                            cols,
                            tile_ep,
                        );
                    }
                }
            }
        }
    }
}

/// Bytes of per-thread i8 B-panel scratch a `k×n` problem needs on
/// kernel `kern` (pair-interleaved, so ~half the f32 figure) — the i32
/// accumulator is accounted separately (`4·m·n`).
pub fn pack_scratch_bytes_i8(kern: &KernelI8, k: usize, n: usize) -> usize {
    if k == 0 || n == 0 {
        return 0;
    }
    NC.min(n).div_ceil(kern.nr) * kern.nr * KC.min(k).div_ceil(2) * 2
}

/// `y = dequant(W·x)` — the dense-layer special case on row-major i8
/// weights (k-consecutive bytes are natural `madd` pairs, so no
/// re-packing is needed). Row-parallel for large layers, mirroring
/// `gemm::matvec`.
#[allow(clippy::too_many_arguments)]
pub fn matvec_i8(
    m: usize,
    k: usize,
    w: &[i8],
    x: &[i8],
    ep: EpilogueI8,
    threads: usize,
    y: &mut [f32],
) {
    matvec_i8_with(kernels::selected_i8(), m, k, w, x, ep, threads, y)
}

/// [`matvec_i8`] on an explicit i8 kernel variant (ISA-parity tests).
#[allow(clippy::too_many_arguments)]
pub fn matvec_i8_with(
    kern: &'static KernelI8,
    m: usize,
    k: usize,
    w: &[i8],
    x: &[i8],
    ep: EpilogueI8,
    threads: usize,
    y: &mut [f32],
) {
    assert_eq!(w.len(), m * k, "matvec_i8: W must be m*k");
    assert_eq!(x.len(), k, "matvec_i8: x must be k");
    assert_eq!(y.len(), m, "matvec_i8: y must be m");
    assert_eq!(ep.scales.len(), m, "matvec_i8: one scale per row");
    if let Some(b) = ep.bias {
        assert_eq!(b.len(), m, "matvec_i8: bias must be m");
    }
    if m == 0 {
        return;
    }
    if k == 0 {
        for (i, out) in y.iter_mut().enumerate() {
            let s = ep.bias.map_or(0.0, |b| b[i]);
            *out = if ep.relu { s.max(0.0) } else { s };
        }
        return;
    }
    let flops = 2.0 * m as f64 * k as f64;
    let t = threads.clamp(1, m);
    if t == 1 || flops < 2e6 {
        kern.matvec_rows(w, x, ep, y, k);
        return;
    }
    let rows_per = m.div_ceil(t);
    std::thread::scope(|scope| {
        let w_blocks = w.chunks(rows_per * k);
        let y_blocks = y.chunks_mut(rows_per);
        for (i, (w_blk, y_blk)) in w_blocks.zip(y_blocks).enumerate() {
            let row0 = i * rows_per;
            let ep_blk = EpilogueI8 {
                scales: &ep.scales[row0..row0 + y_blk.len()],
                bias: ep.bias.map(|b| &b[row0..row0 + y_blk.len()]),
                relu: ep.relu,
            };
            scope.spawn(move || kern.matvec_rows(w_blk, x, ep_blk, y_blk, k));
        }
    });
}

/// Materialize the f32 values a quantized im2col view would dequantize
/// from — test/support helper: quantize `input` with `scale` and return
/// both the i8 buffer and the matching [`QIm2colView`] geometry inputs.
/// (The serving path uses [`QIm2colView::quantize`] into arena memory.)
pub fn quantize_tensor(input: &Tensor, scale: f32) -> Vec<i8> {
    let mut buf = vec![0i8; input.len()];
    quant::quantize_into(&input.data, scale, &mut buf);
    buf
}

/// The f32 `Im2colView` geometry check mirrored for tests: both views
/// over the same conv geometry expose identical `k`/`n`.
pub fn qview_matches_f32_geometry(q: &QIm2colView, f: &Im2colView) -> bool {
    q.k() == f.k() && q.n() == f.n()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::im2col::im2col;
    use crate::util::prng::SplitMix64;

    fn rand_vec(len: usize, seed: u64) -> Vec<f32> {
        let mut r = SplitMix64::new(seed);
        (0..len).map(|_| r.next_symmetric(1.0)).collect()
    }

    fn rand_i8(len: usize, seed: u64) -> Vec<i8> {
        let mut r = SplitMix64::new(seed);
        (0..len)
            .map(|_| (r.next_symmetric(127.0) as i32).clamp(-127, 127) as i8)
            .collect()
    }

    /// Exact integer oracle: i32 accumulate, then the dequant epilogue.
    fn qgemm_naive(
        m: usize,
        k: usize,
        n: usize,
        a: &[i8],
        b: &[i8],
        scales: &[f32],
        bias: Option<&[f32]>,
        relu: bool,
    ) -> Vec<f32> {
        let mut c = vec![0.0f32; m * n];
        for i in 0..m {
            for j in 0..n {
                let mut acc = 0i32;
                for p in 0..k {
                    acc += a[i * k + p] as i32 * b[p * n + j] as i32;
                }
                let v = acc as f32 * scales[i] + bias.map_or(0.0, |bv| bv[i]);
                c[i * n + j] = if relu { v.max(0.0) } else { v };
            }
        }
        c
    }

    /// Dequantize a quantized matrix back to the f32 values the packer
    /// saw, so the naive oracle can run on the exact same ints.
    fn requant_rows(a: &[f32], m: usize, k: usize) -> (Vec<i8>, Vec<f32>) {
        quant::quantize_rows(a, m, k)
    }

    #[test]
    fn prepacked_i8_matches_naive_every_kernel_exactly() {
        // Shapes straddling KC/NC/row-block boundaries, odd k for the
        // pair padding, every compiled-in i8 variant, serial + threaded.
        let shapes = [
            (1usize, 1usize, 1usize),
            (3, 5, 7),
            (4, KC, 16),
            (5, KC + 3, 17),
            (64, 40, NC),
            (67, KC + 9, NC + 17),
            (70, 301, 33),
        ];
        for kern in kernels::supported_i8() {
            let mut scratch = QPackScratch::new();
            for (i, &(m, k, n)) in shapes.iter().enumerate() {
                let a = rand_vec(m * k, 100 + i as u64);
                let b = rand_i8(k * n, 200 + i as u64);
                let bias = rand_vec(m, 300 + i as u64);
                let pa = PackedAI8::pack_with(kern, m, k, &a, 3);
                let (qa, wscales) = requant_rows(&a, m, k);
                // Combined scale: pretend x_scale = 0.02.
                let scales: Vec<f32> = wscales.iter().map(|s| s * 0.02).collect();
                for relu in [false, true] {
                    let want = qgemm_naive(m, k, n, &qa, &b, &scales, Some(&bias), relu);
                    let ep = EpilogueI8 {
                        scales: &scales,
                        bias: Some(&bias),
                        relu,
                    };
                    for threads in [1usize, 3] {
                        let src = DenseBI8::new(k, n, &b);
                        // Dirty output proves the i8 path overwrites.
                        let mut got = vec![9.9f32; m * n];
                        gemm_i8_prepacked_from(&pa, &src, &mut got, ep, threads, &mut scratch);
                        assert_eq!(
                            got,
                            want,
                            "{} case {i} ({m}x{k}x{n}) relu={relu} threads={threads}",
                            kern.name()
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn i8_variants_agree_bitwise_with_each_other() {
        // The cross-ISA claim at the GEMM level: every supported i8
        // kernel produces the same f32 bytes (exact integer accumulators
        // + fixed dequant expression).
        let (m, k, n) = (70, 301, 33);
        let a = rand_vec(m * k, 41);
        let b = rand_i8(k * n, 42);
        let bias = rand_vec(m, 43);
        let (_, wscales) = requant_rows(&a, m, k);
        let scales: Vec<f32> = wscales.iter().map(|s| s * 0.015).collect();
        let ep = EpilogueI8 {
            scales: &scales,
            bias: Some(&bias),
            relu: true,
        };
        let mut outs: Vec<Vec<f32>> = Vec::new();
        for kern in kernels::supported_i8() {
            let pa = PackedAI8::pack_with(kern, m, k, &a, 2);
            let mut scratch = QPackScratch::new();
            let mut c = vec![0.0f32; m * n];
            gemm_i8_prepacked_from(&pa, &DenseBI8::new(k, n, &b), &mut c, ep, 2, &mut scratch);
            outs.push(c);
        }
        for (i, o) in outs.iter().enumerate().skip(1) {
            assert_eq!(o, &outs[0], "i8 variant {i} diverged from scalar-i8");
        }
    }

    #[test]
    fn qim2col_packs_identically_to_materialized_quantized_cols() {
        // Quantize an input, materialize its im2col in i8 (quantized
        // values are exactly representable in f32, so the f32 im2col of
        // the dequantized-int image is exact), and require the virtual
        // view to pack the same bytes.
        let cases = [
            // (c, h, w, k_h, k_w, stride, pad_h, pad_w)
            (3usize, 12usize, 12usize, 3usize, 3usize, 1usize, 1usize, 1usize),
            (2, 11, 7, 3, 5, 2, 0, 2),
            (1, 5, 5, 1, 1, 1, 0, 0),
            (4, 9, 9, 5, 5, 3, 2, 2),
        ];
        for (ci, &(c, h, w, kh, kw, s, ph, pw)) in cases.iter().enumerate() {
            let input = Tensor::from_vec(c, h, w, rand_vec(c * h * w, 700 + ci as u64));
            let scale = quant::act_scale(quant::max_abs(&input.data));
            let q = quantize_tensor(&input, scale);
            let qf = Tensor::from_vec(c, h, w, q.iter().map(|&v| v as f32).collect());
            let (oh, ow) = ((h + 2 * ph - kh) / s + 1, (w + 2 * pw - kw) / s + 1);
            let (k, n) = (c * kh * kw, oh * ow);
            let cols_f = im2col(&qf, kh, kw, s, ph, pw, oh, ow);
            let cols_i8: Vec<i8> = cols_f.iter().map(|&v| v as i8).collect();
            let dense = DenseBI8::new(k, n, &cols_i8);
            let view = QIm2colView::new(&q, c, h, w, kh, kw, s, ph, pw, oh, ow);
            assert_eq!((view.k(), view.n()), (k, n));
            let nr = 16usize;
            for jc in (0..n).step_by(NC) {
                let nc = NC.min(n - jc);
                for pc in (0..k).step_by(KC) {
                    let kc = KC.min(k - pc);
                    let len = nc.div_ceil(nr) * nr * kc.div_ceil(2) * 2;
                    let mut want = vec![55i8; len];
                    let mut got = vec![77i8; len];
                    dense.pack_panel(&mut want, jc, nc, pc, kc, nr);
                    view.pack_panel(&mut got, jc, nc, pc, kc, nr);
                    assert_eq!(got, want, "case {ci} jc={jc} pc={pc}");
                }
            }
        }
    }

    #[test]
    fn matvec_i8_matches_naive_every_kernel() {
        for kern in kernels::supported_i8() {
            for (i, &(m, k)) in [(1usize, 1usize), (7, 9), (64, 257), (130, 1030)]
                .iter()
                .enumerate()
            {
                let w = rand_i8(m * k, 20 + i as u64);
                let x = rand_i8(k, 30 + i as u64);
                let scales: Vec<f32> = (0..m).map(|r| 0.01 + r as f32 * 1e-4).collect();
                let bias = rand_vec(m, 40 + i as u64);
                for relu in [false, true] {
                    let mut want = vec![0.0f32; m];
                    for r in 0..m {
                        let mut acc = 0i32;
                        for p in 0..k {
                            acc += w[r * k + p] as i32 * x[p] as i32;
                        }
                        let v = acc as f32 * scales[r] + bias[r];
                        want[r] = if relu { v.max(0.0) } else { v };
                    }
                    let ep = EpilogueI8 {
                        scales: &scales,
                        bias: Some(&bias),
                        relu,
                    };
                    for threads in [1usize, 4] {
                        let mut y = vec![0.0f32; m];
                        matvec_i8_with(kern, m, k, &w, &x, ep, threads, &mut y);
                        assert_eq!(
                            y,
                            want,
                            "{} case {i} relu={relu} threads={threads}",
                            kern.name()
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn qscratch_stops_growing_after_warmup() {
        let shapes = [(70usize, 301usize, 33usize), (9, 40, 17), (67, KC + 9, 64)];
        let mut scratch = QPackScratch::new();
        let run_all = |scratch: &mut QPackScratch| {
            for (i, &(m, k, n)) in shapes.iter().enumerate() {
                let a = rand_vec(m * k, 7000 + i as u64);
                let b = rand_i8(k * n, 8000 + i as u64);
                let pa = PackedAI8::pack_for_threads(m, k, &a, 2);
                let scales: Vec<f32> = pa.scales().iter().map(|s| s * 0.01).collect();
                let ep = EpilogueI8 {
                    scales: &scales,
                    bias: None,
                    relu: false,
                };
                let mut c = vec![0.0f32; m * n];
                gemm_i8_prepacked_from(&pa, &DenseBI8::new(k, n, &b), &mut c, ep, 2, &mut scratch);
            }
        };
        run_all(&mut scratch);
        let after_warmup = scratch.grow_count();
        assert!(after_warmup > 0, "first pass must have grown the scratch");
        for _ in 0..5 {
            run_all(&mut scratch);
        }
        assert_eq!(
            scratch.grow_count(),
            after_warmup,
            "steady-state i8 GEMM must not grow the scratch"
        );
    }

    #[test]
    fn packed_bytes_shrink_vs_f32() {
        use crate::tensor::gemm::PackedA;
        let (m, k) = (64usize, 576usize);
        let a = rand_vec(m * k, 5);
        let f32p = PackedA::pack_for_threads(m, k, &a, 1);
        let i8p = PackedAI8::pack_for_threads(m, k, &a, 1);
        assert_eq!(i8p.kernel().mr, kernels::selected_i8().mr);
        let ratio = f32p.bytes() as f64 / i8p.bytes() as f64;
        assert!(
            ratio >= 3.5,
            "packed_bytes must shrink >= 3.5x (got {ratio:.2})"
        );
    }

    #[test]
    fn zero_k_and_empty_edges() {
        let mut scratch = QPackScratch::new();
        let pa0 = PackedAI8::pack_for_threads(2, 0, &[], 1);
        let scales = vec![1.0f32, 1.0];
        let bias = vec![1.0f32, -2.0];
        let mut c = vec![9.0f32; 2 * 3];
        gemm_i8_prepacked_from(
            &pa0,
            &DenseBI8::new(0, 3, &[]),
            &mut c,
            EpilogueI8 {
                scales: &scales,
                bias: Some(&bias),
                relu: true,
            },
            1,
            &mut scratch,
        );
        assert_eq!(c, vec![1.0, 1.0, 1.0, 0.0, 0.0, 0.0]);
        let mut y = vec![0.0f32; 2];
        matvec_i8(
            2,
            0,
            &[],
            &[],
            EpilogueI8 {
                scales: &scales,
                bias: Some(&bias),
                relu: false,
            },
            1,
            &mut y,
        );
        assert_eq!(y, vec![1.0, -2.0]);
    }
}
