//! conv2d → im2col + GEMM lowering: the Fast backend's conv path.
//!
//! The column matrix has one row per weight tap `(ic, ky, kx)` and one
//! column per output pixel `(oy, ox)`; with OIHW weights flattened to
//! `[c_out, c_in*k_h*k_w]` the convolution is then exactly
//! `W · im2col(x)`, and bias+ReLU ride in the GEMM epilogue
//! (`tensor::gemm::Epilogue`).
//!
//! Interior/border split: for each `(tap, output row)` pair the valid
//! output columns form one contiguous run (`stride == 1`: a single
//! bounds-check-free `copy_from_slice` of the input row; strided: a tight
//! gather loop), while columns whose receptive field falls outside the
//! image keep the buffer's zero fill — materialized conv padding. The hot
//! interior therefore performs no per-pixel bounds checks at all, unlike
//! the reference `ops::conv2d` loop nest.
//!
//! The GEMM (and the dense matvec) this lowers onto dispatch their inner
//! register tiles through `tensor::kernels` — AVX2+FMA / NEON where the
//! CPU supports them, portable scalar otherwise — with no change to any
//! call site here.
//!
//! [`Im2colView`] is the *implicit* counterpart: it implements
//! `gemm::BPanelProvider`, gathering conv patches directly into the
//! prepacked GEMM's per-thread `KC×NC` B-panel buffer instead of first
//! materializing the full `c_in*k_h*k_w × out_h*out_w` column matrix.
//! The gather reuses the same interior/border split per (tap, output
//! row) segment, so the packed panels are bit-identical to running
//! `pack_b` over a materialized [`im2col`] — only the monolithic `cols`
//! buffer (the largest transient allocation of every compiled conv
//! stage) disappears. `exec::prepack::run_conv` routes the compiled
//! serving path through it.

use super::gemm::{gemm_parallel, matvec, BPanelProvider, Epilogue};
use super::Tensor;

/// Build the column matrix: `c_in*k_h*k_w` rows × `out_h*out_w` columns,
/// row-major. Zero entries materialize the conv padding.
#[allow(clippy::too_many_arguments)]
pub fn im2col(
    input: &Tensor,
    k_h: usize,
    k_w: usize,
    stride: usize,
    pad_h: usize,
    pad_w: usize,
    out_h: usize,
    out_w: usize,
) -> Vec<f32> {
    let mut cols = vec![0.0f32; input.c * k_h * k_w * out_h * out_w];
    im2col_into(input, k_h, k_w, stride, pad_h, pad_w, out_h, out_w, &mut cols);
    cols
}

/// [`im2col`] into a caller-provided buffer (the scratch-arena serving
/// path): uses exactly the first `c_in*k_h*k_w*out_h*out_w` elements of
/// `cols`, re-zeroing them first (padding relies on the zero fill).
#[allow(clippy::too_many_arguments)]
pub fn im2col_into(
    input: &Tensor,
    k_h: usize,
    k_w: usize,
    stride: usize,
    pad_h: usize,
    pad_w: usize,
    out_h: usize,
    out_w: usize,
    cols: &mut [f32],
) {
    let n = out_h * out_w;
    let used = input.c * k_h * k_w * n;
    assert!(cols.len() >= used, "im2col_into: scratch buffer too small");
    let cols = &mut cols[..used];
    cols.fill(0.0);
    let h = input.h as isize;
    let w = input.w as isize;
    for ic in 0..input.c {
        for ky in 0..k_h {
            for kx in 0..k_w {
                let row = (ic * k_h + ky) * k_w + kx;
                let dst_base = row * n;
                for oy in 0..out_h {
                    let iy = (oy * stride + ky) as isize - pad_h as isize;
                    if iy < 0 || iy >= h {
                        continue; // whole output row reads padding
                    }
                    let src_row = input.idx(ic, iy as usize, 0);
                    let dst_row = dst_base + oy * out_w;
                    if stride == 1 {
                        // ix = ox + kx - pad_w must lie in [0, w):
                        // one contiguous run of output columns.
                        let off = kx as isize - pad_w as isize;
                        let lo = (-off).max(0) as usize;
                        let hi = (w - off).min(out_w as isize);
                        if hi > lo as isize {
                            let hi = hi as usize;
                            let src0 = (src_row as isize + lo as isize + off) as usize;
                            cols[dst_row + lo..dst_row + hi]
                                .copy_from_slice(&input.data[src0..src0 + (hi - lo)]);
                        }
                    } else {
                        let dst = &mut cols[dst_row..dst_row + out_w];
                        for (ox, d) in dst.iter_mut().enumerate() {
                            let ix = (ox * stride + kx) as isize - pad_w as isize;
                            if ix >= 0 && ix < w {
                                *d = input.data[src_row + ix as usize];
                            }
                        }
                    }
                }
            }
        }
    }
}

/// A virtual im2col matrix: behaves as the `c_in*k_h*k_w × out_h*out_w`
/// column matrix of a conv input without materializing it. Implements
/// [`BPanelProvider`], so `gemm::gemm_prepacked_from` can consume conv
/// patches panel-by-panel — the whole transient footprint of a conv
/// call shrinks from the full column matrix to one `KC×NC` pack buffer
/// per thread (`gemm::pack_scratch_bytes`).
///
/// Row `(ic*k_h + ky)*k_w + kx` / column `oy*out_w + ox` holds input
/// pixel `(ic, oy*stride + ky - pad_h, ox*stride + kx - pad_w)`, or 0
/// where the receptive field falls outside the image — exactly
/// [`im2col`]'s layout, so packed panels are bit-identical to `pack_b`
/// over the materialized matrix.
pub struct Im2colView<'a> {
    input: &'a Tensor,
    k_h: usize,
    k_w: usize,
    stride: usize,
    pad_h: usize,
    pad_w: usize,
    out_h: usize,
    out_w: usize,
}

impl<'a> Im2colView<'a> {
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        input: &'a Tensor,
        k_h: usize,
        k_w: usize,
        stride: usize,
        pad_h: usize,
        pad_w: usize,
        out_h: usize,
        out_w: usize,
    ) -> Im2colView<'a> {
        assert!(stride >= 1, "im2col view: stride must be >= 1");
        super::ops::assert_conv_fits(input, k_h, k_w, pad_h, pad_w);
        assert_eq!(
            out_h,
            (input.h + 2 * pad_h - k_h) / stride + 1,
            "im2col view: out_h inconsistent with conv geometry"
        );
        assert_eq!(
            out_w,
            (input.w + 2 * pad_w - k_w) / stride + 1,
            "im2col view: out_w inconsistent with conv geometry"
        );
        Im2colView {
            input,
            k_h,
            k_w,
            stride,
            pad_h,
            pad_w,
            out_h,
            out_w,
        }
    }

    /// Gather one tap row's values for `dst.len()` consecutive output
    /// pixels starting at flat output index `j0` (the im2col entries
    /// `[row, j0 .. j0 + dst.len())` for tap `(ic, ky, kx)`). Segments
    /// are split per output row; within a row the stride-1 interior is
    /// one `copy_from_slice` with zero-filled borders, mirroring
    /// [`im2col_into`]'s interior/border split.
    fn gather_tap_cols(&self, ic: usize, ky: usize, kx: usize, j0: usize, dst: &mut [f32]) {
        let input = self.input;
        let h = input.h as isize;
        let w = input.w as isize;
        let mut j = j0;
        let mut done = 0usize;
        while done < dst.len() {
            let oy = j / self.out_w;
            let ox0 = j % self.out_w;
            let seg = (self.out_w - ox0).min(dst.len() - done);
            let d = &mut dst[done..done + seg];
            let iy = (oy * self.stride + ky) as isize - self.pad_h as isize;
            if iy < 0 || iy >= h {
                d.fill(0.0); // whole segment reads vertical padding
            } else {
                let src_row = input.idx(ic, iy as usize, 0);
                if self.stride == 1 {
                    // ix = ox + kx - pad_w must lie in [0, w): the valid
                    // output columns form one contiguous run.
                    let off = kx as isize - self.pad_w as isize;
                    let seg_end = (ox0 + seg) as isize;
                    let lo = (-off).clamp(ox0 as isize, seg_end) as usize;
                    let hi = (w - off).clamp(ox0 as isize, seg_end) as usize;
                    d[..lo - ox0].fill(0.0);
                    if hi > lo {
                        let src0 = (src_row as isize + lo as isize + off) as usize;
                        d[lo - ox0..hi - ox0]
                            .copy_from_slice(&input.data[src0..src0 + (hi - lo)]);
                    }
                    d[hi - ox0..].fill(0.0);
                } else {
                    for (t, dv) in d.iter_mut().enumerate() {
                        let ix =
                            ((ox0 + t) * self.stride + kx) as isize - self.pad_w as isize;
                        *dv = if ix >= 0 && ix < w {
                            input.data[src_row + ix as usize]
                        } else {
                            0.0
                        };
                    }
                }
            }
            done += seg;
            j += seg;
        }
    }
}

impl BPanelProvider for Im2colView<'_> {
    fn k(&self) -> usize {
        self.input.c * self.k_h * self.k_w
    }

    fn n(&self) -> usize {
        self.out_h * self.out_w
    }

    fn pack_panel(
        &self,
        bpack: &mut [f32],
        jc: usize,
        nc: usize,
        pc: usize,
        kc: usize,
        nr: usize,
    ) {
        let n_panels = nc.div_ceil(nr);
        assert!(
            bpack.len() >= n_panels * kc * nr,
            "im2col pack_panel: scratch buffer too small"
        );
        for jt in 0..n_panels {
            let j0 = jc + jt * nr;
            let cols = nr.min(jc + nc - j0);
            let panel = &mut bpack[jt * kc * nr..(jt + 1) * kc * nr];
            for (p, dst) in panel.chunks_exact_mut(nr).enumerate() {
                // Decompose the virtual B row into its weight tap.
                let row = pc + p;
                let kx = row % self.k_w;
                let ky = (row / self.k_w) % self.k_h;
                let ic = row / (self.k_w * self.k_h);
                self.gather_tap_cols(ic, ky, kx, j0, &mut dst[..cols]);
                for v in &mut dst[cols..] {
                    *v = 0.0;
                }
            }
        }
    }
}

/// A batch of [`Im2colView`]s presented as ONE virtual B matrix: the
/// member column matrices concatenated along the output-pixel axis, so
/// an `n`-column conv GEMM becomes a `batch*n`-column GEMM against the
/// same prepacked weights. Column `j` belongs to member `j / n1` at
/// local output pixel `j % n1` (`n1 = out_h*out_w`, identical across
/// members — batched requests share the model geometry).
///
/// Bit-identity with batch=1: the microkernel accumulates every output
/// element over the same `KC`-blocked k sequence regardless of which
/// pack-panel column the element lands in, so batching only relocates
/// columns — each `C[i, j]` sees exactly the FMA order it sees in a
/// single-member GEMM.
pub struct BatchIm2colView<'a> {
    views: Vec<Im2colView<'a>>,
    /// Columns per member (`out_h * out_w`).
    n1: usize,
}

impl<'a> BatchIm2colView<'a> {
    pub fn new(views: Vec<Im2colView<'a>>) -> BatchIm2colView<'a> {
        assert!(!views.is_empty(), "batched im2col view: no members");
        let (k, n1) = (views[0].k(), views[0].n());
        for v in &views[1..] {
            assert_eq!(
                (v.k(), v.n()),
                (k, n1),
                "batched im2col view: member geometry mismatch"
            );
        }
        BatchIm2colView { views, n1 }
    }
}

impl BPanelProvider for BatchIm2colView<'_> {
    fn k(&self) -> usize {
        self.views[0].k()
    }

    fn n(&self) -> usize {
        self.views.len() * self.n1
    }

    fn pack_panel(
        &self,
        bpack: &mut [f32],
        jc: usize,
        nc: usize,
        pc: usize,
        kc: usize,
        nr: usize,
    ) {
        let n_panels = nc.div_ceil(nr);
        assert!(
            bpack.len() >= n_panels * kc * nr,
            "batched im2col pack_panel: scratch buffer too small"
        );
        let geo = &self.views[0];
        for jt in 0..n_panels {
            let j0 = jc + jt * nr;
            let cols = nr.min(jc + nc - j0);
            let panel = &mut bpack[jt * kc * nr..(jt + 1) * kc * nr];
            for (p, dst) in panel.chunks_exact_mut(nr).enumerate() {
                let row = pc + p;
                let kx = row % geo.k_w;
                let ky = (row / geo.k_w) % geo.k_h;
                let ic = row / (geo.k_w * geo.k_h);
                // A tile of nr columns may straddle member boundaries:
                // gather each member's contiguous span separately.
                let mut filled = 0usize;
                while filled < cols {
                    let j = j0 + filled;
                    let member = j / self.n1;
                    let lj = j % self.n1;
                    let take = (self.n1 - lj).min(cols - filled);
                    self.views[member].gather_tap_cols(
                        ic,
                        ky,
                        kx,
                        lj,
                        &mut dst[filled..filled + take],
                    );
                    filled += take;
                }
                for v in &mut dst[cols..] {
                    *v = 0.0;
                }
            }
        }
    }
}

/// Fast 2-D convolution — same contract as `ops::conv2d` (OIHW weights,
/// CHW input, per-axis zero padding, optional bias, fused ReLU) computed
/// as a blocked GEMM over the im2col matrix. `threads > 1` splits output
/// channels across scoped threads (`gemm_parallel`).
#[allow(clippy::too_many_arguments)]
pub fn conv2d_gemm(
    input: &Tensor,
    weight: &[f32],
    bias: Option<&[f32]>,
    c_out: usize,
    k_h: usize,
    k_w: usize,
    stride: usize,
    pad_h: usize,
    pad_w: usize,
    relu: bool,
    threads: usize,
) -> Tensor {
    let c_in = input.c;
    assert_eq!(
        weight.len(),
        c_out * c_in * k_h * k_w,
        "weight size mismatch"
    );
    if let Some(b) = bias {
        assert_eq!(b.len(), c_out, "bias size mismatch");
    }
    assert!(stride >= 1);
    super::ops::assert_conv_fits(input, k_h, k_w, pad_h, pad_w);
    let out_h = (input.h + 2 * pad_h - k_h) / stride + 1;
    let out_w = (input.w + 2 * pad_w - k_w) / stride + 1;
    let k = c_in * k_h * k_w;
    let n = out_h * out_w;
    let cols = im2col(input, k_h, k_w, stride, pad_h, pad_w, out_h, out_w);
    let mut out = Tensor::zeros(c_out, out_h, out_w);
    gemm_parallel(
        c_out,
        k,
        n,
        weight,
        &cols,
        &mut out.data,
        Epilogue { bias, relu },
        threads,
    );
    out
}

/// Fast dense layer — same contract as `ops::dense`, computed as a
/// lane-vectorized (and, for large layers, row-parallel) matvec.
pub fn dense_gemm(
    input: &Tensor,
    weight: &[f32],
    bias: Option<&[f32]>,
    c_out: usize,
    relu: bool,
    threads: usize,
) -> Tensor {
    let c_in = input.len();
    assert_eq!(weight.len(), c_out * c_in, "dense weight size mismatch");
    if let Some(b) = bias {
        assert_eq!(b.len(), c_out, "dense bias size mismatch");
    }
    let mut y = vec![0.0f32; c_out];
    matvec(c_out, c_in, weight, &input.data, bias, relu, threads, &mut y);
    Tensor::vector(y)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::ops;
    use crate::util::prng::SplitMix64;

    fn rand_vec(len: usize, seed: u64) -> Vec<f32> {
        let mut r = SplitMix64::new(seed);
        (0..len).map(|_| r.next_symmetric(1.0)).collect()
    }

    fn rand_tensor(c: usize, h: usize, w: usize, seed: u64) -> Tensor {
        Tensor::from_vec(c, h, w, rand_vec(c * h * w, seed))
    }

    #[test]
    fn im2col_identity_for_1x1_kernel() {
        // 1x1 kernel, stride 1, no pad: the column matrix IS the input.
        let t = rand_tensor(3, 4, 5, 1);
        let cols = im2col(&t, 1, 1, 1, 0, 0, 4, 5);
        assert_eq!(cols, t.data);
    }

    #[test]
    fn im2col_materializes_padding_as_zeros() {
        let t = Tensor::from_vec(1, 2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        // 3x3 kernel, pad 1: out 2x2, 9 rows of 4 cols.
        let cols = im2col(&t, 3, 3, 1, 1, 1, 2, 2);
        assert_eq!(cols.len(), 9 * 4);
        // Center tap (ky=1, kx=1 → row 4) sees the raw image.
        let center = 4;
        assert_eq!(&cols[center * 4..center * 4 + 4], &[1.0, 2.0, 3.0, 4.0]);
        // Top-left tap (ky=0, kx=0) reads above/left of the image for all
        // but the bottom-right output; only out (1,1) sees pixel (0,0).
        assert_eq!(&cols[0..4], &[0.0, 0.0, 0.0, 1.0]);
    }

    #[test]
    fn im2col_into_reuses_dirty_oversized_scratch() {
        let t = rand_tensor(2, 6, 5, 9);
        let fresh = im2col(&t, 3, 3, 1, 1, 1, 6, 5);
        // A dirty, oversized scratch: the used prefix must be re-zeroed
        // and rebuilt exactly; the rest must stay untouched.
        let mut scratch = vec![7.0f32; fresh.len() + 64];
        im2col_into(&t, 3, 3, 1, 1, 1, 6, 5, &mut scratch);
        assert_eq!(&scratch[..fresh.len()], &fresh[..]);
        assert!(scratch[fresh.len()..].iter().all(|v| *v == 7.0));
    }

    #[test]
    fn conv_gemm_matches_reference_basic() {
        let t = rand_tensor(3, 9, 8, 2);
        let w = rand_vec(4 * 3 * 3 * 3, 3);
        let b = rand_vec(4, 4);
        let want = ops::conv2d(&t, &w, Some(&b), 4, 3, 3, 1, 1, 1, true);
        let got = conv2d_gemm(&t, &w, Some(&b), 4, 3, 3, 1, 1, 1, true, 1);
        assert!(
            got.allclose(&want, 1e-5, 1e-5),
            "diff={}",
            got.max_abs_diff(&want)
        );
    }

    #[test]
    fn conv_gemm_matches_reference_strided_asymmetric_pad() {
        let t = rand_tensor(2, 11, 7, 5);
        let w = rand_vec(3 * 2 * 3 * 5, 6);
        let want = ops::conv2d(&t, &w, None, 3, 3, 5, 2, 0, 2, false);
        let got = conv2d_gemm(&t, &w, None, 3, 3, 5, 2, 0, 2, false, 1);
        assert!(
            got.allclose(&want, 1e-5, 1e-5),
            "diff={}",
            got.max_abs_diff(&want)
        );
    }

    #[test]
    fn dense_gemm_matches_reference_basic() {
        let x = Tensor::vector(rand_vec(37, 7));
        let w = rand_vec(11 * 37, 8);
        let b = rand_vec(11, 9);
        let want = ops::dense(&x, &w, Some(&b), 11, true);
        let got = dense_gemm(&x, &w, Some(&b), 11, true, 1);
        assert!(
            got.allclose(&want, 1e-5, 1e-5),
            "diff={}",
            got.max_abs_diff(&want)
        );
    }

    #[test]
    #[should_panic(expected = "conv2d: kernel")]
    fn conv_gemm_oversized_kernel_panics_cleanly() {
        let t = Tensor::zeros(1, 2, 2);
        let w = vec![0.0; 25];
        conv2d_gemm(&t, &w, None, 1, 5, 5, 1, 0, 0, false, 1);
    }

    /// Conv geometries straddling the GEMM blocking boundaries:
    /// `n > NC` (column-panel split), `k > KC` (depth split), strided
    /// and asymmetric padding, pointwise, and stride > kernel.
    fn view_cases() -> Vec<(usize, usize, usize, usize, usize, usize, usize, usize)> {
        vec![
            // (c, h, w, k_h, k_w, stride, pad_h, pad_w)
            (3, 32, 32, 3, 3, 1, 1, 1), // n = 1024 crosses NC = 512
            (30, 10, 9, 3, 3, 1, 1, 1), // k = 270 crosses KC = 256
            (2, 11, 7, 3, 5, 2, 0, 2),  // strided, asymmetric pad
            (1, 5, 5, 1, 1, 1, 0, 0),   // pointwise: view == input
            (4, 9, 9, 5, 5, 3, 2, 2),   // big window, stride 3
            (2, 6, 5, 3, 3, 2, 1, 0),
        ]
    }

    #[test]
    fn im2col_view_packs_identically_to_materialized_pack() {
        use crate::tensor::gemm::{DenseB, KC, NC};
        for (ci, &(c, h, w, kh, kw, s, ph, pw)) in view_cases().iter().enumerate() {
            let t = rand_tensor(c, h, w, 500 + ci as u64);
            let (oh, ow) = ((h + 2 * ph - kh) / s + 1, (w + 2 * pw - kw) / s + 1);
            let (k, n) = (c * kh * kw, oh * ow);
            let cols = im2col(&t, kh, kw, s, ph, pw, oh, ow);
            let view = Im2colView::new(&t, kh, kw, s, ph, pw, oh, ow);
            assert_eq!((view.k(), view.n()), (k, n));
            let dense = DenseB::new(k, n, &cols);
            // Every (k block, column block) the prepacked GEMM would
            // request, at every compiled-in tile width, must pack
            // bit-identically — distinct dirty sentinels prove the whole
            // prefix (including zero padding) is overwritten.
            for nr in [4usize, 8, 16] {
                for jc in (0..n).step_by(NC) {
                    let nc = NC.min(n - jc);
                    for pc in (0..k).step_by(KC) {
                        let kc = KC.min(k - pc);
                        let len = nc.div_ceil(nr) * nr * kc;
                        let mut want = vec![55.0f32; len];
                        let mut got = vec![77.0f32; len];
                        dense.pack_panel(&mut want, jc, nc, pc, kc, nr);
                        view.pack_panel(&mut got, jc, nc, pc, kc, nr);
                        assert_eq!(got, want, "case {ci} nr={nr} jc={jc} pc={pc}");
                    }
                }
            }
        }
    }

    #[test]
    fn batched_view_packs_identically_to_concatenated_materialized_pack() {
        use crate::tensor::gemm::{DenseB, KC, NC};
        for (ci, &(c, h, w, kh, kw, s, ph, pw)) in view_cases().iter().enumerate() {
            let (oh, ow) = ((h + 2 * ph - kh) / s + 1, (w + 2 * pw - kw) / s + 1);
            let (k, n1) = (c * kh * kw, oh * ow);
            for b in [1usize, 3, 4] {
                let members: Vec<Tensor> = (0..b)
                    .map(|m| rand_tensor(c, h, w, 900 + 16 * ci as u64 + m as u64))
                    .collect();
                // Reference: the member column matrices concatenated
                // along the output-pixel axis, row by row.
                let per: Vec<Vec<f32>> = members
                    .iter()
                    .map(|t| im2col(t, kh, kw, s, ph, pw, oh, ow))
                    .collect();
                let n = b * n1;
                let mut cols = vec![0.0f32; k * n];
                for r in 0..k {
                    for (m, p) in per.iter().enumerate() {
                        cols[r * n + m * n1..r * n + (m + 1) * n1]
                            .copy_from_slice(&p[r * n1..(r + 1) * n1]);
                    }
                }
                let dense = DenseB::new(k, n, &cols);
                let view = BatchIm2colView::new(
                    members
                        .iter()
                        .map(|t| Im2colView::new(t, kh, kw, s, ph, pw, oh, ow))
                        .collect(),
                );
                assert_eq!((view.k(), view.n()), (k, n));
                // nr values that do NOT divide n1 force pack tiles to
                // straddle member boundaries — the case the batched
                // gather splits by hand.
                for nr in [4usize, 8, 16] {
                    for jc in (0..n).step_by(NC) {
                        let nc = NC.min(n - jc);
                        for pc in (0..k).step_by(KC) {
                            let kc = KC.min(k - pc);
                            let len = nc.div_ceil(nr) * nr * kc;
                            let mut want = vec![55.0f32; len];
                            let mut got = vec![77.0f32; len];
                            dense.pack_panel(&mut want, jc, nc, pc, kc, nr);
                            view.pack_panel(&mut got, jc, nc, pc, kc, nr);
                            assert_eq!(got, want, "case {ci} b={b} nr={nr} jc={jc} pc={pc}");
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn batched_view_gemm_bit_identical_to_per_member_gemms() {
        // The batching claim itself: one GEMM over the batched view
        // must reproduce each member's single-request GEMM *bitwise*
        // (column block m of the batched C == member m's C), on every
        // compiled-in microkernel, serial and threaded.
        use crate::tensor::gemm::{gemm_prepacked_from, PackScratch, PackedA};
        use crate::tensor::kernels;
        for kern in kernels::supported() {
            let mut scratch = PackScratch::new();
            for (ci, &(c, h, w, kh, kw, s, ph, pw)) in view_cases().iter().enumerate() {
                let (oh, ow) = ((h + 2 * ph - kh) / s + 1, (w + 2 * pw - kw) / s + 1);
                let (k, n1) = (c * kh * kw, oh * ow);
                let c_out = 70;
                let weight = rand_vec(c_out * k, 1000 + ci as u64);
                let bias = rand_vec(c_out, 1100 + ci as u64);
                let pa = PackedA::pack_with(kern, c_out, k, &weight, 2);
                let b = 3usize;
                let members: Vec<Tensor> = (0..b)
                    .map(|m| rand_tensor(c, h, w, 1200 + 16 * ci as u64 + m as u64))
                    .collect();
                let ep = Epilogue {
                    bias: Some(&bias),
                    relu: true,
                };
                for threads in [1usize, 3] {
                    let mut want = vec![vec![0.0f32; c_out * n1]; b];
                    for (t, out) in members.iter().zip(want.iter_mut()) {
                        let view = Im2colView::new(t, kh, kw, s, ph, pw, oh, ow);
                        gemm_prepacked_from(&pa, &view, out, ep, threads, &mut scratch);
                    }
                    let bview = BatchIm2colView::new(
                        members
                            .iter()
                            .map(|t| Im2colView::new(t, kh, kw, s, ph, pw, oh, ow))
                            .collect(),
                    );
                    let n = b * n1;
                    let mut got = vec![0.0f32; c_out * n];
                    gemm_prepacked_from(&pa, &bview, &mut got, ep, threads, &mut scratch);
                    for (m, w1) in want.iter().enumerate() {
                        for i in 0..c_out {
                            assert_eq!(
                                &got[i * n + m * n1..i * n + (m + 1) * n1],
                                &w1[i * n1..(i + 1) * n1],
                                "{} case {ci} member {m} row {i} threads={threads}",
                                kern.name()
                            );
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn fused_view_gemm_bit_identical_to_materialized_every_kernel() {
        // The implicit-GEMM path packs the same values into the same
        // panel layout, so the result must equal the dense path *bitwise*
        // (not just within tolerance) on every compiled-in microkernel,
        // serial and row-split-threaded.
        use crate::tensor::gemm::{gemm_prepacked, gemm_prepacked_from, PackScratch, PackedA};
        use crate::tensor::kernels;
        for kern in kernels::supported() {
            let mut scratch = PackScratch::new();
            for (ci, &(c, h, w, kh, kw, s, ph, pw)) in view_cases().iter().enumerate() {
                let t = rand_tensor(c, h, w, 600 + ci as u64);
                let (oh, ow) = ((h + 2 * ph - kh) / s + 1, (w + 2 * pw - kw) / s + 1);
                let (k, n) = (c * kh * kw, oh * ow);
                // 70 output rows push the big cases past the GEMM's
                // parallel-path FLOP threshold, so the scoped-thread
                // row split runs against the *virtual* provider too.
                let c_out = 70;
                let weight = rand_vec(c_out * k, 700 + ci as u64);
                let bias = rand_vec(c_out, 800 + ci as u64);
                let pa = PackedA::pack_with(kern, c_out, k, &weight, 2);
                let cols = im2col(&t, kh, kw, s, ph, pw, oh, ow);
                for relu in [false, true] {
                    let ep = Epilogue {
                        bias: Some(&bias),
                        relu,
                    };
                    for threads in [1usize, 3] {
                        let mut want = vec![0.0f32; c_out * n];
                        gemm_prepacked(&pa, n, &cols, &mut want, ep, threads, &mut scratch);
                        let view = Im2colView::new(&t, kh, kw, s, ph, pw, oh, ow);
                        let mut got = vec![0.0f32; c_out * n];
                        gemm_prepacked_from(&pa, &view, &mut got, ep, threads, &mut scratch);
                        assert_eq!(
                            got,
                            want,
                            "{} case {ci} relu={relu} threads={threads}",
                            kern.name()
                        );
                    }
                }
            }
        }
    }
}
