//! conv2d → im2col + GEMM lowering: the Fast backend's conv path.
//!
//! The column matrix has one row per weight tap `(ic, ky, kx)` and one
//! column per output pixel `(oy, ox)`; with OIHW weights flattened to
//! `[c_out, c_in*k_h*k_w]` the convolution is then exactly
//! `W · im2col(x)`, and bias+ReLU ride in the GEMM epilogue
//! (`tensor::gemm::Epilogue`).
//!
//! Interior/border split: for each `(tap, output row)` pair the valid
//! output columns form one contiguous run (`stride == 1`: a single
//! bounds-check-free `copy_from_slice` of the input row; strided: a tight
//! gather loop), while columns whose receptive field falls outside the
//! image keep the buffer's zero fill — materialized conv padding. The hot
//! interior therefore performs no per-pixel bounds checks at all, unlike
//! the reference `ops::conv2d` loop nest.
//!
//! The GEMM (and the dense matvec) this lowers onto dispatch their inner
//! register tiles through `tensor::kernels` — AVX2+FMA / NEON where the
//! CPU supports them, portable scalar otherwise — with no change to any
//! call site here.

use super::gemm::{gemm_parallel, matvec, Epilogue};
use super::Tensor;

/// Build the column matrix: `c_in*k_h*k_w` rows × `out_h*out_w` columns,
/// row-major. Zero entries materialize the conv padding.
#[allow(clippy::too_many_arguments)]
pub fn im2col(
    input: &Tensor,
    k_h: usize,
    k_w: usize,
    stride: usize,
    pad_h: usize,
    pad_w: usize,
    out_h: usize,
    out_w: usize,
) -> Vec<f32> {
    let mut cols = vec![0.0f32; input.c * k_h * k_w * out_h * out_w];
    im2col_into(input, k_h, k_w, stride, pad_h, pad_w, out_h, out_w, &mut cols);
    cols
}

/// [`im2col`] into a caller-provided buffer (the scratch-arena serving
/// path): uses exactly the first `c_in*k_h*k_w*out_h*out_w` elements of
/// `cols`, re-zeroing them first (padding relies on the zero fill).
#[allow(clippy::too_many_arguments)]
pub fn im2col_into(
    input: &Tensor,
    k_h: usize,
    k_w: usize,
    stride: usize,
    pad_h: usize,
    pad_w: usize,
    out_h: usize,
    out_w: usize,
    cols: &mut [f32],
) {
    let n = out_h * out_w;
    let used = input.c * k_h * k_w * n;
    assert!(cols.len() >= used, "im2col_into: scratch buffer too small");
    let cols = &mut cols[..used];
    cols.fill(0.0);
    let h = input.h as isize;
    let w = input.w as isize;
    for ic in 0..input.c {
        for ky in 0..k_h {
            for kx in 0..k_w {
                let row = (ic * k_h + ky) * k_w + kx;
                let dst_base = row * n;
                for oy in 0..out_h {
                    let iy = (oy * stride + ky) as isize - pad_h as isize;
                    if iy < 0 || iy >= h {
                        continue; // whole output row reads padding
                    }
                    let src_row = input.idx(ic, iy as usize, 0);
                    let dst_row = dst_base + oy * out_w;
                    if stride == 1 {
                        // ix = ox + kx - pad_w must lie in [0, w):
                        // one contiguous run of output columns.
                        let off = kx as isize - pad_w as isize;
                        let lo = (-off).max(0) as usize;
                        let hi = (w - off).min(out_w as isize);
                        if hi > lo as isize {
                            let hi = hi as usize;
                            let src0 = (src_row as isize + lo as isize + off) as usize;
                            cols[dst_row + lo..dst_row + hi]
                                .copy_from_slice(&input.data[src0..src0 + (hi - lo)]);
                        }
                    } else {
                        let dst = &mut cols[dst_row..dst_row + out_w];
                        for (ox, d) in dst.iter_mut().enumerate() {
                            let ix = (ox * stride + kx) as isize - pad_w as isize;
                            if ix >= 0 && ix < w {
                                *d = input.data[src_row + ix as usize];
                            }
                        }
                    }
                }
            }
        }
    }
}

/// Fast 2-D convolution — same contract as `ops::conv2d` (OIHW weights,
/// CHW input, per-axis zero padding, optional bias, fused ReLU) computed
/// as a blocked GEMM over the im2col matrix. `threads > 1` splits output
/// channels across scoped threads (`gemm_parallel`).
#[allow(clippy::too_many_arguments)]
pub fn conv2d_gemm(
    input: &Tensor,
    weight: &[f32],
    bias: Option<&[f32]>,
    c_out: usize,
    k_h: usize,
    k_w: usize,
    stride: usize,
    pad_h: usize,
    pad_w: usize,
    relu: bool,
    threads: usize,
) -> Tensor {
    let c_in = input.c;
    assert_eq!(
        weight.len(),
        c_out * c_in * k_h * k_w,
        "weight size mismatch"
    );
    if let Some(b) = bias {
        assert_eq!(b.len(), c_out, "bias size mismatch");
    }
    assert!(stride >= 1);
    super::ops::assert_conv_fits(input, k_h, k_w, pad_h, pad_w);
    let out_h = (input.h + 2 * pad_h - k_h) / stride + 1;
    let out_w = (input.w + 2 * pad_w - k_w) / stride + 1;
    let k = c_in * k_h * k_w;
    let n = out_h * out_w;
    let cols = im2col(input, k_h, k_w, stride, pad_h, pad_w, out_h, out_w);
    let mut out = Tensor::zeros(c_out, out_h, out_w);
    gemm_parallel(
        c_out,
        k,
        n,
        weight,
        &cols,
        &mut out.data,
        Epilogue { bias, relu },
        threads,
    );
    out
}

/// Fast dense layer — same contract as `ops::dense`, computed as a
/// lane-vectorized (and, for large layers, row-parallel) matvec.
pub fn dense_gemm(
    input: &Tensor,
    weight: &[f32],
    bias: Option<&[f32]>,
    c_out: usize,
    relu: bool,
    threads: usize,
) -> Tensor {
    let c_in = input.len();
    assert_eq!(weight.len(), c_out * c_in, "dense weight size mismatch");
    if let Some(b) = bias {
        assert_eq!(b.len(), c_out, "dense bias size mismatch");
    }
    let mut y = vec![0.0f32; c_out];
    matvec(c_out, c_in, weight, &input.data, bias, relu, threads, &mut y);
    Tensor::vector(y)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::ops;
    use crate::util::prng::SplitMix64;

    fn rand_vec(len: usize, seed: u64) -> Vec<f32> {
        let mut r = SplitMix64::new(seed);
        (0..len).map(|_| r.next_symmetric(1.0)).collect()
    }

    fn rand_tensor(c: usize, h: usize, w: usize, seed: u64) -> Tensor {
        Tensor::from_vec(c, h, w, rand_vec(c * h * w, seed))
    }

    #[test]
    fn im2col_identity_for_1x1_kernel() {
        // 1x1 kernel, stride 1, no pad: the column matrix IS the input.
        let t = rand_tensor(3, 4, 5, 1);
        let cols = im2col(&t, 1, 1, 1, 0, 0, 4, 5);
        assert_eq!(cols, t.data);
    }

    #[test]
    fn im2col_materializes_padding_as_zeros() {
        let t = Tensor::from_vec(1, 2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        // 3x3 kernel, pad 1: out 2x2, 9 rows of 4 cols.
        let cols = im2col(&t, 3, 3, 1, 1, 1, 2, 2);
        assert_eq!(cols.len(), 9 * 4);
        // Center tap (ky=1, kx=1 → row 4) sees the raw image.
        let center = 4;
        assert_eq!(&cols[center * 4..center * 4 + 4], &[1.0, 2.0, 3.0, 4.0]);
        // Top-left tap (ky=0, kx=0) reads above/left of the image for all
        // but the bottom-right output; only out (1,1) sees pixel (0,0).
        assert_eq!(&cols[0..4], &[0.0, 0.0, 0.0, 1.0]);
    }

    #[test]
    fn im2col_into_reuses_dirty_oversized_scratch() {
        let t = rand_tensor(2, 6, 5, 9);
        let fresh = im2col(&t, 3, 3, 1, 1, 1, 6, 5);
        // A dirty, oversized scratch: the used prefix must be re-zeroed
        // and rebuilt exactly; the rest must stay untouched.
        let mut scratch = vec![7.0f32; fresh.len() + 64];
        im2col_into(&t, 3, 3, 1, 1, 1, 6, 5, &mut scratch);
        assert_eq!(&scratch[..fresh.len()], &fresh[..]);
        assert!(scratch[fresh.len()..].iter().all(|v| *v == 7.0));
    }

    #[test]
    fn conv_gemm_matches_reference_basic() {
        let t = rand_tensor(3, 9, 8, 2);
        let w = rand_vec(4 * 3 * 3 * 3, 3);
        let b = rand_vec(4, 4);
        let want = ops::conv2d(&t, &w, Some(&b), 4, 3, 3, 1, 1, 1, true);
        let got = conv2d_gemm(&t, &w, Some(&b), 4, 3, 3, 1, 1, 1, true, 1);
        assert!(
            got.allclose(&want, 1e-5, 1e-5),
            "diff={}",
            got.max_abs_diff(&want)
        );
    }

    #[test]
    fn conv_gemm_matches_reference_strided_asymmetric_pad() {
        let t = rand_tensor(2, 11, 7, 5);
        let w = rand_vec(3 * 2 * 3 * 5, 6);
        let want = ops::conv2d(&t, &w, None, 3, 3, 5, 2, 0, 2, false);
        let got = conv2d_gemm(&t, &w, None, 3, 3, 5, 2, 0, 2, false, 1);
        assert!(
            got.allclose(&want, 1e-5, 1e-5),
            "diff={}",
            got.max_abs_diff(&want)
        );
    }

    #[test]
    fn dense_gemm_matches_reference_basic() {
        let x = Tensor::vector(rand_vec(37, 7));
        let w = rand_vec(11 * 37, 8);
        let b = rand_vec(11, 9);
        let want = ops::dense(&x, &w, Some(&b), 11, true);
        let got = dense_gemm(&x, &w, Some(&b), 11, true, 1);
        assert!(
            got.allclose(&want, 1e-5, 1e-5),
            "diff={}",
            got.max_abs_diff(&want)
        );
    }

    #[test]
    #[should_panic(expected = "conv2d: kernel")]
    fn conv_gemm_oversized_kernel_panics_cleanly() {
        let t = Tensor::zeros(1, 2, 2);
        let w = vec![0.0; 25];
        conv2d_gemm(&t, &w, None, 1, 5, 5, 1, 0, 0, false, 1);
    }
}
