//! Deterministic tensor initialization — the rust half of the mirrored
//! weight generator (see `util::prng` and `python/compile/weights.py`).
//!
//! Naming convention: `"{model}/{op}/w"` and `"{model}/{op}/b"` for weights
//! and biases, `"{model}/input"` for the synthetic inference input. Both
//! languages derive the stream seed from the same FNV-1a hash, so the rust
//! coordinator can slice weights for device shards and feed PJRT
//! executables the *same* numbers the python oracle used.

use super::Tensor;
use crate::util::prng::{named_tensor, SplitMix64};

/// Default weight scale. Small magnitudes keep deep VGG activations in a
/// well-conditioned f32 range without normalization layers.
pub const WEIGHT_SCALE: f32 = 0.05;

/// Conv weight tensor, laid out OIHW (c_out, c_in, k_h, k_w) —
/// the layout jax's `lax.conv_general_dilated` uses for its default
/// dimension numbers and the layout `ops::conv2d` consumes.
pub fn conv_weight(name: &str, c_out: usize, c_in: usize, k_h: usize, k_w: usize) -> Vec<f32> {
    named_tensor(name, c_out * c_in * k_h * k_w, WEIGHT_SCALE)
}

/// Dense weight, laid out (c_out, c_in) row-major.
pub fn dense_weight(name: &str, c_out: usize, c_in: usize) -> Vec<f32> {
    named_tensor(name, c_out * c_in, WEIGHT_SCALE)
}

/// Bias vector of length `c_out`.
pub fn bias(name: &str, c_out: usize) -> Vec<f32> {
    named_tensor(name, c_out, WEIGHT_SCALE)
}

/// Synthetic input activation in [0, 1) (image-like).
pub fn input_tensor(name: &str, c: usize, h: usize, w: usize) -> Tensor {
    let mut rng = SplitMix64::from_name(name);
    let data = (0..c * h * w).map(|_| rng.next_f32()).collect();
    Tensor::from_vec(c, h, w, data)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        assert_eq!(
            conv_weight("m/c1/w", 2, 3, 5, 5),
            conv_weight("m/c1/w", 2, 3, 5, 5)
        );
        assert_ne!(conv_weight("m/c1/w", 2, 3, 5, 5), conv_weight("m/c2/w", 2, 3, 5, 5));
    }

    #[test]
    fn input_range() {
        let t = input_tensor("m/input", 3, 8, 8);
        assert!(t.data.iter().all(|v| (0.0..1.0).contains(v)));
        assert_eq!(t.len(), 3 * 8 * 8);
    }

    #[test]
    fn sizes() {
        assert_eq!(conv_weight("x", 4, 3, 5, 5).len(), 4 * 3 * 25);
        assert_eq!(dense_weight("x", 10, 20).len(), 200);
        assert_eq!(bias("x", 7).len(), 7);
    }
}
