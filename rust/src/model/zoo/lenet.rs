//! LeNet-5 (LeCun et al., 1998) on MNIST 1×28×28 — Table 1 row 1:
//! "7-layer CNN, 2 conv + 3 fc".
//!
//! The 28×28 variant pads conv1 by 2 (the classic 32×32 receptive field),
//! giving the canonical 400-feature flatten into fc1.

use crate::model::graph::Model;
use crate::model::op::{Op, OpKind, Shape};

pub fn lenet() -> Model {
    let ops = vec![
        Op::new(
            "conv1",
            OpKind::Conv2d {
                c_in: 1,
                c_out: 6,
                k_h: 5,
                k_w: 5,
                stride: 1,
                pad: 2,
                relu: true,
            },
        ),
        Op::new("pool1", OpKind::MaxPool { k: 2, stride: 2 }),
        Op::new(
            "conv2",
            OpKind::Conv2d {
                c_in: 6,
                c_out: 16,
                k_h: 5,
                k_w: 5,
                stride: 1,
                pad: 0,
                relu: true,
            },
        ),
        Op::new("pool2", OpKind::MaxPool { k: 2, stride: 2 }),
        Op::new("flatten", OpKind::Flatten),
        Op::new(
            "fc1",
            OpKind::Dense {
                c_in: 400,
                c_out: 120,
                relu: true,
            },
        ),
        Op::new(
            "fc2",
            OpKind::Dense {
                c_in: 120,
                c_out: 84,
                relu: true,
            },
        ),
        Op::new(
            "fc3",
            OpKind::Dense {
                c_in: 84,
                c_out: 10,
                relu: false,
            },
        ),
    ];
    Model::new("lenet", Shape::new(1, 28, 28), ops)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes_follow_the_classic_pipeline() {
        let m = lenet();
        let s = m.shapes();
        assert_eq!(s[0], Shape::new(6, 28, 28)); // conv1 (pad 2)
        assert_eq!(s[1], Shape::new(6, 14, 14)); // pool1
        assert_eq!(s[2], Shape::new(16, 10, 10)); // conv2
        assert_eq!(s[3], Shape::new(16, 5, 5)); // pool2
        assert_eq!(s[4], Shape::vector(400)); // flatten
        assert_eq!(s[7], Shape::vector(10)); // fc3
    }

    #[test]
    fn parameter_count() {
        // conv1: 6*1*25+6=156; conv2: 16*6*25+16=2416;
        // fc1: 120*400+120=48120; fc2: 84*120+84=10164; fc3: 10*84+10=850.
        assert_eq!(lenet().total_weight_bytes() / 4, 156 + 2416 + 48120 + 10164 + 850);
    }
}
