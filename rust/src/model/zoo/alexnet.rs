//! AlexNet (Krizhevsky et al., 2012), single-tower variant, on ImageNet
//! 3×224×224 — Table 1 row 2: "12-layer CNN, 5 conv + 3 fc".
//!
//! This is also the origin of the paper's **OC baseline**: the original
//! two-GPU AlexNet split its operators along the output-channel dimension.

use crate::model::graph::Model;
use crate::model::op::{Op, OpKind, Shape};

pub fn alexnet() -> Model {
    let conv = |name: &str, c_in, c_out, k, stride, pad| {
        Op::new(
            name,
            OpKind::Conv2d {
                c_in,
                c_out,
                k_h: k,
                k_w: k,
                stride,
                pad,
                relu: true,
            },
        )
    };
    let ops = vec![
        conv("conv1", 3, 96, 11, 4, 2),
        Op::new("pool1", OpKind::MaxPool { k: 3, stride: 2 }),
        conv("conv2", 96, 256, 5, 1, 2),
        Op::new("pool2", OpKind::MaxPool { k: 3, stride: 2 }),
        conv("conv3", 256, 384, 3, 1, 1),
        conv("conv4", 384, 384, 3, 1, 1),
        conv("conv5", 384, 256, 3, 1, 1),
        Op::new("pool5", OpKind::MaxPool { k: 3, stride: 2 }),
        Op::new("flatten", OpKind::Flatten),
        Op::new(
            "fc6",
            OpKind::Dense {
                c_in: 9216,
                c_out: 4096,
                relu: true,
            },
        ),
        Op::new(
            "fc7",
            OpKind::Dense {
                c_in: 4096,
                c_out: 4096,
                relu: true,
            },
        ),
        Op::new(
            "fc8",
            OpKind::Dense {
                c_in: 4096,
                c_out: 1000,
                relu: false,
            },
        ),
    ];
    Model::new("alexnet", Shape::new(3, 224, 224), ops)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn canonical_shapes() {
        let m = alexnet();
        let s = m.shapes();
        assert_eq!(s[0], Shape::new(96, 55, 55)); // conv1
        assert_eq!(s[1], Shape::new(96, 27, 27)); // pool1
        assert_eq!(s[2], Shape::new(256, 27, 27)); // conv2
        assert_eq!(s[3], Shape::new(256, 13, 13)); // pool2
        assert_eq!(s[6], Shape::new(256, 13, 13)); // conv5
        assert_eq!(s[7], Shape::new(256, 6, 6)); // pool5
        assert_eq!(s[8], Shape::vector(9216)); // flatten
    }

    #[test]
    fn fc_dominates_parameters() {
        // The paper's Fig. 5 analysis hinges on this: FC layers hold the
        // bulk of AlexNet's parameters, so a strategy that does not
        // partition FC (CoEdge) has a much larger peak memory.
        let m = alexnet();
        let fc_bytes: u64 = m
            .ops
            .iter()
            .filter(|o| o.kind_tag() == "fc")
            .map(|o| o.weight_bytes())
            .sum();
        assert!(fc_bytes as f64 / m.total_weight_bytes() as f64 > 0.9);
    }
}
