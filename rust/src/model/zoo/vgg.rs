//! The VGG family (Simonyan & Zisserman, 2014) on ImageNet 3×224×224.
//!
//! Table 1 row 3 uses VGG11 ("17-layer CNN, 8 conv + 3 fc"); Fig. 6 sweeps
//! VGG11/13/16/19. All variants share the 3×3/pad-1 conv idiom with
//! 2×2/stride-2 max-pools between blocks and the 4096-4096-1000 classifier.
//!
//! `vgg_mini` is a structurally identical but tiny network (CIFAR-sized
//! input, narrow channels) used where real tensor execution must be fast:
//! the PJRT e2e example and the distributed-executor tests.

use crate::model::graph::Model;
use crate::model::op::{Op, OpKind, Shape};

/// Block widths per variant: each entry is (out_channels, convs_in_block).
fn config(depth: usize) -> Vec<(usize, usize)> {
    match depth {
        11 => vec![(64, 1), (128, 1), (256, 2), (512, 2), (512, 2)],
        13 => vec![(64, 2), (128, 2), (256, 2), (512, 2), (512, 2)],
        16 => vec![(64, 2), (128, 2), (256, 3), (512, 3), (512, 3)],
        19 => vec![(64, 2), (128, 2), (256, 4), (512, 4), (512, 4)],
        _ => panic!("unsupported VGG depth {depth} (use 11/13/16/19)"),
    }
}

/// Build a VGG-`depth` model.
pub fn vgg(depth: usize) -> Model {
    let mut ops = Vec::new();
    let mut c_in = 3;
    for (block, (width, n_convs)) in config(depth).into_iter().enumerate() {
        for i in 0..n_convs {
            ops.push(Op::new(
                format!("conv{}_{}", block + 1, i + 1),
                OpKind::Conv2d {
                    c_in,
                    c_out: width,
                    k_h: 3,
                    k_w: 3,
                    stride: 1,
                    pad: 1,
                    relu: true,
                },
            ));
            c_in = width;
        }
        ops.push(Op::new(
            format!("pool{}", block + 1),
            OpKind::MaxPool { k: 2, stride: 2 },
        ));
    }
    ops.push(Op::new("flatten", OpKind::Flatten));
    ops.push(Op::new(
        "fc1",
        OpKind::Dense {
            c_in: 512 * 7 * 7,
            c_out: 4096,
            relu: true,
        },
    ));
    ops.push(Op::new(
        "fc2",
        OpKind::Dense {
            c_in: 4096,
            c_out: 4096,
            relu: true,
        },
    ));
    ops.push(Op::new(
        "fc3",
        OpKind::Dense {
            c_in: 4096,
            c_out: 1000,
            relu: false,
        },
    ));
    Model::new(format!("vgg{depth}"), Shape::new(3, 224, 224), ops)
}

pub fn vgg11() -> Model {
    vgg(11)
}

pub fn vgg13() -> Model {
    vgg(13)
}

pub fn vgg16() -> Model {
    vgg(16)
}

pub fn vgg19() -> Model {
    vgg(19)
}

/// Tiny VGG-style network for real-execution tests: 3×32×32 input,
/// three conv blocks (8/16/32 channels), two FC layers, 10 classes.
pub fn vgg_mini() -> Model {
    let conv = |name: &str, c_in, c_out| {
        Op::new(
            name,
            OpKind::Conv2d {
                c_in,
                c_out,
                k_h: 3,
                k_w: 3,
                stride: 1,
                pad: 1,
                relu: true,
            },
        )
    };
    let ops = vec![
        conv("conv1", 3, 8),
        Op::new("pool1", OpKind::MaxPool { k: 2, stride: 2 }),
        conv("conv2", 8, 16),
        Op::new("pool2", OpKind::MaxPool { k: 2, stride: 2 }),
        conv("conv3", 16, 32),
        Op::new("pool3", OpKind::MaxPool { k: 2, stride: 2 }),
        Op::new("flatten", OpKind::Flatten),
        Op::new(
            "fc1",
            OpKind::Dense {
                c_in: 32 * 4 * 4,
                c_out: 64,
                relu: true,
            },
        ),
        Op::new(
            "fc2",
            OpKind::Dense {
                c_in: 64,
                c_out: 10,
                relu: false,
            },
        ),
    ];
    Model::new("vgg_mini", Shape::new(3, 32, 32), ops)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn vgg11_is_table1_row3() {
        let m = vgg11();
        assert_eq!(m.count_kind("conv"), 8);
        assert_eq!(m.count_kind("fc"), 3);
        // 8 conv + 5 pool + flatten + 3 fc = 17 ops; the paper's
        // "17-layer CNN" counts conv+pool+fc+flatten comparably.
        assert_eq!(*m.shapes().last().unwrap(), Shape::vector(1000));
    }

    #[test]
    fn deeper_variants_monotone_in_flops() {
        let f: Vec<f64> = [11, 13, 16, 19].iter().map(|d| vgg(*d).total_flops()).collect();
        assert!(f.windows(2).all(|w| w[0] < w[1]), "{f:?}");
    }

    #[test]
    fn feature_map_before_classifier_is_7x7x512() {
        for d in [11, 13, 16, 19] {
            let m = vgg(d);
            let flat_idx = m
                .ops
                .iter()
                .position(|o| o.kind_tag() == "flatten")
                .unwrap();
            assert_eq!(m.in_shape(flat_idx), Shape::new(512, 7, 7), "vgg{d}");
        }
    }

    #[test]
    fn vgg_mini_is_small() {
        let m = vgg_mini();
        assert!(m.total_weight_bytes() < 500_000);
        assert_eq!(*m.shapes().last().unwrap(), Shape::vector(10));
    }

    #[test]
    #[should_panic]
    fn bad_depth_panics() {
        vgg(15);
    }
}
