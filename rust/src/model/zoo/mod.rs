//! Model zoo — the evaluation models of Table 1 (LeNet, AlexNet, VGG11)
//! plus the Fig. 6 VGG family (VGG13/16/19) and a `vgg_mini` used by the
//! real-execution examples/tests (small enough to run through PJRT-CPU and
//! the reference ops quickly).

mod alexnet;
mod lenet;
mod vgg;

pub use alexnet::alexnet;
pub use lenet::lenet;
pub use vgg::{vgg, vgg11, vgg13, vgg16, vgg19, vgg_mini};

use super::graph::Model;

/// Table-1 style metadata for a zoo model.
#[derive(Debug, Clone)]
pub struct ModelInfo {
    pub name: &'static str,
    pub description: &'static str,
    pub dataset: &'static str,
}

/// Look up a model by name ("lenet", "alexnet", "vgg11", "vgg13",
/// "vgg16", "vgg19", "vgg_mini").
pub fn by_name(name: &str) -> Option<Model> {
    match name {
        "lenet" => Some(lenet()),
        "alexnet" => Some(alexnet()),
        "vgg11" => Some(vgg11()),
        "vgg13" => Some(vgg13()),
        "vgg16" => Some(vgg16()),
        "vgg19" => Some(vgg19()),
        "vgg_mini" => Some(vgg_mini()),
        _ => None,
    }
}

/// All zoo models (excluding vgg_mini, which is a test vehicle).
pub fn all_models() -> Vec<Model> {
    vec![lenet(), alexnet(), vgg11(), vgg13(), vgg16(), vgg19()]
}

/// The three Fig. 4 / Fig. 5 evaluation models.
pub fn fig4_models() -> Vec<Model> {
    vec![lenet(), alexnet(), vgg11()]
}

/// The four Fig. 6 VGG variants.
pub fn fig6_models() -> Vec<Model> {
    vec![vgg11(), vgg13(), vgg16(), vgg19()]
}

/// Table 1 metadata.
pub fn table1() -> Vec<ModelInfo> {
    vec![
        ModelInfo {
            name: "lenet",
            description: "7-layer CNN",
            dataset: "MNIST",
        },
        ModelInfo {
            name: "alexnet",
            description: "12-layer CNN",
            dataset: "ImageNet",
        },
        ModelInfo {
            name: "vgg11",
            description: "17-layer CNN",
            dataset: "ImageNet",
        },
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_counts_match_paper() {
        // Table 1: LeNet 2 conv + 3 fc; AlexNet 5 conv + 3 fc;
        // VGG11 8 conv + 3 fc.
        let cases = [
            ("lenet", 2, 3),
            ("alexnet", 5, 3),
            ("vgg11", 8, 3),
            ("vgg13", 10, 3),
            ("vgg16", 13, 3),
            ("vgg19", 16, 3),
        ];
        for (name, conv, fc) in cases {
            let m = by_name(name).unwrap();
            assert_eq!(m.count_kind("conv"), conv, "{name} conv count");
            assert_eq!(m.count_kind("fc"), fc, "{name} fc count");
        }
    }

    #[test]
    fn by_name_unknown_is_none() {
        assert!(by_name("resnet50").is_none());
    }

    #[test]
    fn known_parameter_counts() {
        // Classic parameter-count sanity anchors (weights + biases).
        let alex = alexnet();
        let params = alex.total_weight_bytes() / 4;
        // AlexNet (single-tower) ≈ 62.3M params.
        assert!(
            (60_000_000..65_000_000).contains(&params),
            "alexnet params = {params}"
        );
        let v16 = vgg16();
        let params = v16.total_weight_bytes() / 4;
        // VGG16 ≈ 138M params.
        assert!(
            (135_000_000..142_000_000).contains(&params),
            "vgg16 params = {params}"
        );
    }

    #[test]
    fn output_is_classifier() {
        for m in all_models() {
            let out = *m.shapes().last().unwrap();
            assert_eq!(out.h, 1);
            assert_eq!(out.w, 1);
            assert!(out.c == 10 || out.c == 1000, "{}: {:?}", m.name, out);
        }
    }
}
