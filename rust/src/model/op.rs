//! Operator definitions — the paper's §3 operator tuple
//! `(c_in, c_out, w_k, h_k, s, p)` plus the shape-preserving helpers
//! (pool / flatten) CNNs are built from.
//!
//! ReLU is fused into conv/dense (`relu: bool`) exactly as deployment
//! frameworks do; standalone `Relu` exists for models that need it between
//! non-weighted ops.

use crate::util::json::Json;

/// 3-D activation shape (batch elided; `Dense` activations use
/// `c = features, h = w = 1`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct Shape {
    pub c: usize,
    pub h: usize,
    pub w: usize,
}

impl Shape {
    pub fn new(c: usize, h: usize, w: usize) -> Self {
        Self { c, h, w }
    }

    pub fn vector(n: usize) -> Self {
        Self { c: n, h: 1, w: 1 }
    }

    pub fn elems(&self) -> usize {
        self.c * self.h * self.w
    }

    pub fn bytes(&self) -> u64 {
        self.elems() as u64 * 4
    }

    pub fn to_json(&self) -> Json {
        Json::arr(vec![
            Json::num(self.c as f64),
            Json::num(self.h as f64),
            Json::num(self.w as f64),
        ])
    }
}

/// Operator kinds. `Conv2d`/`Dense` are the *weighted* ops the partitioning
/// strategies act on; the rest are passthrough ops that inherit the layout
/// of their producer (DESIGN.md §2).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum OpKind {
    Conv2d {
        c_in: usize,
        c_out: usize,
        k_h: usize,
        k_w: usize,
        stride: usize,
        pad: usize,
        relu: bool,
    },
    Dense {
        c_in: usize,
        c_out: usize,
        relu: bool,
    },
    MaxPool {
        k: usize,
        stride: usize,
    },
    Flatten,
    Relu,
}

/// A named operator in the chain.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Op {
    pub name: String,
    pub kind: OpKind,
}

impl Op {
    pub fn new(name: impl Into<String>, kind: OpKind) -> Self {
        Self {
            name: name.into(),
            kind,
        }
    }

    /// Weighted ops carry parameters the strategies partition
    /// (conv & dense); passthrough ops do not.
    pub fn is_weighted(&self) -> bool {
        matches!(self.kind, OpKind::Conv2d { .. } | OpKind::Dense { .. })
    }

    /// Output-channel count of a weighted op.
    pub fn c_out(&self) -> Option<usize> {
        match self.kind {
            OpKind::Conv2d { c_out, .. } | OpKind::Dense { c_out, .. } => Some(c_out),
            _ => None,
        }
    }

    /// Input-channel count of a weighted op.
    pub fn c_in(&self) -> Option<usize> {
        match self.kind {
            OpKind::Conv2d { c_in, .. } | OpKind::Dense { c_in, .. } => Some(c_in),
            _ => None,
        }
    }

    /// Output shape for a given input shape. Panics on inconsistent wiring
    /// (a model-zoo bug, not a runtime condition).
    pub fn out_shape(&self, input: Shape) -> Shape {
        match self.kind {
            OpKind::Conv2d {
                c_in,
                c_out,
                k_h,
                k_w,
                stride,
                pad,
                ..
            } => {
                assert_eq!(input.c, c_in, "op {}: input channels mismatch", self.name);
                assert!(
                    input.h + 2 * pad >= k_h && input.w + 2 * pad >= k_w,
                    "op {}: conv kernel {}x{} exceeds padded input {}x{} (pad={})",
                    self.name,
                    k_h,
                    k_w,
                    input.h + 2 * pad,
                    input.w + 2 * pad,
                    pad
                );
                let h = (input.h + 2 * pad - k_h) / stride + 1;
                let w = (input.w + 2 * pad - k_w) / stride + 1;
                Shape::new(c_out, h, w)
            }
            OpKind::Dense { c_in, c_out, .. } => {
                assert_eq!(
                    input.elems(),
                    c_in,
                    "op {}: dense input features mismatch",
                    self.name
                );
                Shape::vector(c_out)
            }
            OpKind::MaxPool { k, stride } => {
                assert!(
                    input.h >= k && input.w >= k,
                    "op {}: pool window {}x{} exceeds input {}x{}",
                    self.name,
                    k,
                    k,
                    input.h,
                    input.w
                );
                Shape::new(
                    input.c,
                    (input.h - k) / stride + 1,
                    (input.w - k) / stride + 1,
                )
            }
            OpKind::Flatten => Shape::vector(input.elems()),
            OpKind::Relu => input,
        }
    }

    /// FLOPs to evaluate this op on `input` (multiply-add = 2 FLOPs,
    /// the convention the paper's eq. (7) workloads use).
    pub fn flops(&self, input: Shape) -> f64 {
        let out = self.out_shape(input);
        match self.kind {
            OpKind::Conv2d {
                c_in, k_h, k_w, ..
            } => 2.0 * out.elems() as f64 * (c_in * k_h * k_w) as f64,
            OpKind::Dense { c_in, c_out, .. } => 2.0 * (c_in * c_out) as f64,
            OpKind::MaxPool { k, .. } => out.elems() as f64 * (k * k) as f64,
            OpKind::Flatten => 0.0,
            OpKind::Relu => input.elems() as f64,
        }
    }

    /// Parameter bytes (weights + bias), f32.
    pub fn weight_bytes(&self) -> u64 {
        match self.kind {
            OpKind::Conv2d {
                c_in,
                c_out,
                k_h,
                k_w,
                ..
            } => 4 * (c_out * c_in * k_h * k_w + c_out) as u64,
            OpKind::Dense { c_in, c_out, .. } => 4 * (c_out * c_in + c_out) as u64,
            _ => 0,
        }
    }

    /// Short kind tag for reports.
    pub fn kind_tag(&self) -> &'static str {
        match self.kind {
            OpKind::Conv2d { .. } => "conv",
            OpKind::Dense { .. } => "fc",
            OpKind::MaxPool { .. } => "pool",
            OpKind::Flatten => "flatten",
            OpKind::Relu => "relu",
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn conv_shape_inference() {
        let op = Op::new(
            "c1",
            OpKind::Conv2d {
                c_in: 1,
                c_out: 6,
                k_h: 5,
                k_w: 5,
                stride: 1,
                pad: 0,
                relu: true,
            },
        );
        let out = op.out_shape(Shape::new(1, 28, 28));
        assert_eq!(out, Shape::new(6, 24, 24));
        assert_eq!(op.flops(Shape::new(1, 28, 28)), 2.0 * 6.0 * 24.0 * 24.0 * 25.0);
        assert_eq!(op.weight_bytes(), 4 * (6 * 25 + 6));
    }

    #[test]
    fn pool_flatten_dense_chain() {
        let s = Shape::new(6, 24, 24);
        let pool = Op::new("p", OpKind::MaxPool { k: 2, stride: 2 });
        let s2 = pool.out_shape(s);
        assert_eq!(s2, Shape::new(6, 12, 12));
        let flat = Op::new("f", OpKind::Flatten);
        let s3 = flat.out_shape(s2);
        assert_eq!(s3, Shape::vector(864));
        let fc = Op::new(
            "fc",
            OpKind::Dense {
                c_in: 864,
                c_out: 10,
                relu: false,
            },
        );
        assert_eq!(fc.out_shape(s3), Shape::vector(10));
        assert_eq!(fc.flops(s3), 2.0 * 864.0 * 10.0);
    }

    #[test]
    #[should_panic]
    fn channel_mismatch_panics() {
        let op = Op::new(
            "c",
            OpKind::Conv2d {
                c_in: 3,
                c_out: 8,
                k_h: 3,
                k_w: 3,
                stride: 1,
                pad: 1,
                relu: false,
            },
        );
        op.out_shape(Shape::new(4, 8, 8));
    }

    #[test]
    #[should_panic(expected = "conv kernel")]
    fn oversized_conv_kernel_panics_cleanly() {
        let op = Op::new(
            "c",
            OpKind::Conv2d {
                c_in: 1,
                c_out: 1,
                k_h: 9,
                k_w: 9,
                stride: 1,
                pad: 0,
                relu: false,
            },
        );
        op.out_shape(Shape::new(1, 4, 4));
    }

    #[test]
    #[should_panic(expected = "pool window")]
    fn oversized_pool_window_panics_cleanly() {
        let op = Op::new("p", OpKind::MaxPool { k: 5, stride: 1 });
        op.out_shape(Shape::new(1, 4, 4));
    }

    #[test]
    fn weighted_flags() {
        assert!(Op::new(
            "d",
            OpKind::Dense {
                c_in: 4,
                c_out: 2,
                relu: false
            }
        )
        .is_weighted());
        assert!(!Op::new("p", OpKind::MaxPool { k: 2, stride: 2 }).is_weighted());
        assert!(!Op::new("f", OpKind::Flatten).is_weighted());
    }
}
