//! Model IR: sequential CNN chains, shape/workload accounting, and the
//! evaluation model zoo (Table 1 + Fig. 6 variants).

pub mod graph;
pub mod op;
pub mod zoo;

pub use graph::{Model, Stage};
pub use op::{Op, OpKind, Shape};
