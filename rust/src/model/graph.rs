//! Model IR: a sequential operator chain with shape inference and
//! per-operator workload/memory accounting — everything the cost model
//! (eqs. 1, 7) needs to evaluate a partition plan.
//!
//! CNNs in the paper (LeNet/AlexNet/VGG) are pure chains, so the IR is a
//! `Vec<Op>`; the *weighted-op view* (`weighted_indices`) with attached
//! passthrough ops is what the partitioners and the segmentation algorithm
//! operate on (DESIGN.md §2).

use super::op::{Op, OpKind, Shape};
use crate::util::json::Json;

/// A sequential CNN model.
///
/// Shape inference and the weighted-stage view are computed once at
/// construction and cached — they sit on the hot path of every solver
/// (see EXPERIMENTS.md §Perf).
#[derive(Debug, Clone)]
pub struct Model {
    pub name: String,
    pub input: Shape,
    pub ops: Vec<Op>,
    /// Cached: output shape of each op.
    shapes: Vec<Shape>,
    /// Cached: weighted-stage decomposition.
    stages: Vec<Stage>,
}

/// A weighted op together with the passthrough ops that directly follow it
/// (pool/flatten/relu inherit the producer's partition layout).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Stage {
    /// Index of the weighted op in `Model::ops`.
    pub op_idx: usize,
    /// Indices `[op_idx+1 .. tail_end)` are the attached passthroughs.
    pub tail_end: usize,
}

impl Model {
    pub fn new(name: impl Into<String>, input: Shape, ops: Vec<Op>) -> Self {
        assert!(
            ops.first().map(|o| o.is_weighted()).unwrap_or(false),
            "model must start with a weighted op"
        );
        // shape inference (panics early on inconsistent chains)
        let mut shapes = Vec::with_capacity(ops.len());
        let mut cur = input;
        for op in &ops {
            cur = op.out_shape(cur);
            shapes.push(cur);
        }
        // weighted-stage decomposition
        let mut stages = Vec::new();
        let n = ops.len();
        let mut i = 0;
        while i < n {
            assert!(
                ops[i].is_weighted(),
                "passthrough op {} with no preceding weighted op",
                ops[i].name
            );
            let mut j = i + 1;
            while j < n && !ops[j].is_weighted() {
                j += 1;
            }
            stages.push(Stage {
                op_idx: i,
                tail_end: j,
            });
            i = j;
        }
        Self {
            name: name.into(),
            input,
            ops,
            shapes,
            stages,
        }
    }

    /// Shape after each op: `shapes()[i]` is the *output* of `ops[i]`;
    /// the input of `ops[i]` is `shapes()[i-1]` (or `self.input` for i=0).
    pub fn shapes(&self) -> &[Shape] {
        &self.shapes
    }

    /// Input shape of op `i`.
    #[inline]
    pub fn in_shape(&self, i: usize) -> Shape {
        if i == 0 {
            self.input
        } else {
            self.shapes[i - 1]
        }
    }

    /// Output shape of op `i`.
    #[inline]
    pub fn out_shape(&self, i: usize) -> Shape {
        self.shapes[i]
    }

    /// FLOPs of op `i`.
    pub fn flops(&self, i: usize) -> f64 {
        self.ops[i].flops(self.in_shape(i))
    }

    /// Total model FLOPs.
    pub fn total_flops(&self) -> f64 {
        (0..self.ops.len()).map(|i| self.flops(i)).sum()
    }

    /// Total parameter bytes.
    pub fn total_weight_bytes(&self) -> u64 {
        self.ops.iter().map(|o| o.weight_bytes()).sum()
    }

    /// Number of conv / fc ops (Table 1 columns).
    pub fn count_kind(&self, tag: &str) -> usize {
        self.ops.iter().filter(|o| o.kind_tag() == tag).count()
    }

    /// The weighted-op view: each `Stage` is a conv/fc op plus the
    /// passthrough ops attached behind it (cached).
    #[inline]
    pub fn stages(&self) -> &[Stage] {
        &self.stages
    }

    /// FLOPs of a whole stage (weighted op + its passthrough tail).
    pub fn stage_flops(&self, s: Stage) -> f64 {
        (s.op_idx..s.tail_end).map(|i| self.flops(i)).sum()
    }

    /// Output shape of a stage (after its passthrough tail).
    pub fn stage_out_shape(&self, s: Stage) -> Shape {
        self.out_shape(s.tail_end - 1)
    }

    /// Output shape of a stage *before* any trailing `Flatten` — the
    /// spatial view row-partitioning operates on (a flatten is a pure
    /// re-view: a device owning spatial rows owns the corresponding
    /// flattened elements).
    pub fn stage_spatial_out_shape(&self, s: Stage) -> Shape {
        let mut cur = self.in_shape(s.op_idx);
        for i in s.op_idx..s.tail_end {
            if matches!(self.ops[i].kind, OpKind::Flatten) {
                break;
            }
            cur = self.ops[i].out_shape(cur);
        }
        cur
    }

    /// Whether any op in the stage's tail is a pooling op (matters for
    /// row-partitioned execution halo accounting).
    pub fn stage_has_pool(&self, s: Stage) -> bool {
        (s.op_idx + 1..s.tail_end).any(|i| matches!(self.ops[i].kind, OpKind::MaxPool { .. }))
    }

    /// One-line summary for reports.
    pub fn summary(&self) -> String {
        format!(
            "{}: {} ops ({} conv, {} fc), {:.1} MFLOP, {} params",
            self.name,
            self.ops.len(),
            self.count_kind("conv"),
            self.count_kind("fc"),
            self.total_flops() / 1e6,
            self.total_weight_bytes() / 4,
        )
    }

    /// JSON description (used by `iop models --json` and test goldens).
    pub fn to_json(&self) -> Json {
        let shapes = self.shapes();
        Json::obj(vec![
            ("name", Json::str(self.name.clone())),
            ("input", self.input.to_json()),
            (
                "ops",
                Json::arr(
                    self.ops
                        .iter()
                        .enumerate()
                        .map(|(i, o)| {
                            Json::obj(vec![
                                ("name", Json::str(o.name.clone())),
                                ("kind", Json::str(o.kind_tag())),
                                ("out", shapes[i].to_json()),
                                ("flops", Json::num(self.flops(i))),
                                ("weight_bytes", Json::num(o.weight_bytes() as f64)),
                            ])
                        })
                        .collect(),
                ),
            ),
            ("total_flops", Json::num(self.total_flops())),
            ("total_weight_bytes", Json::num(self.total_weight_bytes() as f64)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::zoo;

    #[test]
    fn stages_group_passthroughs() {
        let m = zoo::lenet();
        let stages = m.stages();
        // LeNet: conv1(+pool), conv2(+pool+flatten), fc1, fc2, fc3
        assert_eq!(stages.len(), 5);
        assert_eq!(stages[0].tail_end - stages[0].op_idx, 2); // conv1, pool1
        assert_eq!(stages[1].tail_end - stages[1].op_idx, 3); // conv2, pool2, flatten
        for s in &stages[2..] {
            assert_eq!(s.tail_end - s.op_idx, 1);
        }
    }

    #[test]
    fn shapes_consistent() {
        let m = zoo::lenet();
        let shapes = m.shapes();
        assert_eq!(shapes.last().unwrap(), &Shape::vector(10));
        assert_eq!(m.in_shape(0), m.input);
        for i in 1..m.ops.len() {
            assert_eq!(m.in_shape(i), shapes[i - 1]);
        }
    }

    #[test]
    fn totals_positive() {
        for m in zoo::all_models() {
            assert!(m.total_flops() > 0.0, "{}", m.name);
            assert!(m.total_weight_bytes() > 0, "{}", m.name);
            assert!(!m.stages().is_empty());
        }
    }

    #[test]
    fn json_roundtrip_fields() {
        let j = zoo::lenet().to_json();
        assert_eq!(j.get("name").as_str(), Some("lenet"));
        assert_eq!(
            j.get("ops").as_arr().unwrap().len(),
            zoo::lenet().ops.len()
        );
    }
}
