//! Device and cluster models — the `(f, r)_j` / `b` substrate of §3.
//!
//! The paper's testbed is a set of AIoT boards on a shared wireless medium;
//! we model each device by its compute capability `f` (FLOP/s) and memory
//! capacity `r` (bytes), and the cluster by a shared bandwidth `b` plus a
//! per-connection establishment latency `t_est` (the Fig. 6 sweep
//! parameter). See DESIGN.md §4 for the substitution record.

use crate::util::json::Json;

/// One cooperative device: `(f, r)_j` in the paper's notation.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Device {
    /// Compute capability `f_j` in FLOP/s.
    pub flops_per_sec: f64,
    /// Available memory `r_j` in bytes.
    pub mem_bytes: u64,
}

impl Device {
    pub fn new(flops_per_sec: f64, mem_bytes: u64) -> Self {
        assert!(flops_per_sec > 0.0, "device compute must be positive");
        Self {
            flops_per_sec,
            mem_bytes,
        }
    }
}

/// A cooperative cluster: devices + the shared communication medium.
#[derive(Debug, Clone, PartialEq)]
pub struct Cluster {
    pub devices: Vec<Device>,
    /// Link bandwidth `b`, bytes/second (paper eq. 8 divides by `b`).
    pub bandwidth_bps: f64,
    /// Connection establishment latency, seconds per connection
    /// (Fig. 6 x-axis, 1–8 ms).
    pub t_est: f64,
}

impl Cluster {
    pub fn new(devices: Vec<Device>, bandwidth_bps: f64, t_est: f64) -> Self {
        assert!(!devices.is_empty(), "cluster needs at least one device");
        assert!(bandwidth_bps > 0.0);
        assert!(t_est >= 0.0);
        Self {
            devices,
            bandwidth_bps,
            t_est,
        }
    }

    /// Homogeneous cluster of `m` identical devices.
    pub fn homogeneous(
        m: usize,
        flops_per_sec: f64,
        mem_bytes: u64,
        bandwidth_bps: f64,
        t_est: f64,
    ) -> Self {
        Self::new(
            vec![Device::new(flops_per_sec, mem_bytes); m],
            bandwidth_bps,
            t_est,
        )
    }

    pub fn m(&self) -> usize {
        self.devices.len()
    }

    /// Total cluster compute, `Σ_j f_j`.
    pub fn total_flops_per_sec(&self) -> f64 {
        self.devices.iter().map(|d| d.flops_per_sec).sum()
    }

    /// Relative compute share of each device (sums to 1).
    pub fn compute_shares(&self) -> Vec<f64> {
        let total = self.total_flops_per_sec();
        self.devices.iter().map(|d| d.flops_per_sec / total).collect()
    }

    /// Seconds to push `bytes` over the shared medium (eq. 8).
    pub fn xfer_secs(&self, bytes: u64) -> f64 {
        bytes as f64 / self.bandwidth_bps
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            (
                "devices",
                Json::arr(
                    self.devices
                        .iter()
                        .map(|d| {
                            Json::obj(vec![
                                ("flops_per_sec", Json::num(d.flops_per_sec)),
                                ("mem_bytes", Json::num(d.mem_bytes as f64)),
                            ])
                        })
                        .collect(),
                ),
            ),
            ("bandwidth_bps", Json::num(self.bandwidth_bps)),
            ("t_est", Json::num(self.t_est)),
        ])
    }

    pub fn from_json(j: &Json) -> Option<Cluster> {
        let devices = j
            .get("devices")
            .as_arr()?
            .iter()
            .map(|d| {
                Some(Device::new(
                    d.get("flops_per_sec").as_f64()?,
                    d.get("mem_bytes").as_f64()? as u64,
                ))
            })
            .collect::<Option<Vec<_>>>()?;
        Some(Cluster::new(
            devices,
            j.get("bandwidth_bps").as_f64()?,
            j.get("t_est").as_f64()?,
        ))
    }
}

/// Named cluster presets used across examples / benches / tests.
pub mod profiles {
    use super::*;

    /// 1 MiB = 2^20 bytes.
    pub const MIB: u64 = 1 << 20;

    /// The default evaluation testbed for Fig. 4 / Fig. 5: three identical
    /// IoT-class boards (≈0.6 GFLOP/s effective CNN throughput, 512 MiB),
    /// 50 Mbit/s shared wireless, 4 ms connection establishment (mid-range
    /// of the Fig. 6 sweep). Calibration notes in EXPERIMENTS.md §Calib.
    pub fn paper_default() -> Cluster {
        Cluster::homogeneous(3, 0.6e9, 512 * MIB, 50e6 / 8.0, 4e-3)
    }

    /// Same testbed with a configurable establishment latency (Fig. 6).
    pub fn paper_with_t_est(t_est: f64) -> Cluster {
        let mut c = paper_default();
        c.t_est = t_est;
        c
    }

    /// A heterogeneous triple: one fast hub and two slower leaf nodes.
    pub fn heterogeneous() -> Cluster {
        Cluster::new(
            vec![
                Device::new(1.2e9, 1024 * MIB),
                Device::new(0.6e9, 512 * MIB),
                Device::new(0.3e9, 256 * MIB),
            ],
            50e6 / 8.0,
            4e-3,
        )
    }

    /// Memory-starved cluster for constraint (eq. 1) stress tests.
    pub fn tiny_memory(m: usize, mem: u64) -> Cluster {
        Cluster::homogeneous(m, 0.6e9, mem, 50e6 / 8.0, 4e-3)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shares_sum_to_one() {
        let c = profiles::heterogeneous();
        let s: f64 = c.compute_shares().iter().sum();
        assert!((s - 1.0).abs() < 1e-12);
        // fastest device gets the biggest share
        let shares = c.compute_shares();
        assert!(shares[0] > shares[1] && shares[1] > shares[2]);
    }

    #[test]
    fn xfer_time() {
        let c = Cluster::homogeneous(2, 1e9, 1 << 30, 12.5e6, 0.0);
        assert!((c.xfer_secs(12_500_000) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn json_roundtrip() {
        let c = profiles::heterogeneous();
        let j = c.to_json();
        let c2 = Cluster::from_json(&j).unwrap();
        assert_eq!(c, c2);
    }

    #[test]
    #[should_panic]
    fn empty_cluster_panics() {
        Cluster::new(vec![], 1.0, 0.0);
    }
}
