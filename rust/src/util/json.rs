//! Minimal, dependency-free JSON substrate (the build image has no serde).
//!
//! Implements a complete recursive-descent parser and a pretty/compact
//! writer for the JSON value model. Used for the artifact `manifest.json`,
//! cluster/experiment config files, and machine-readable metric reports.
//!
//! Scope notes:
//! * Numbers are stored as `f64` (ample for manifests and metrics).
//! * Strings support the standard escapes plus `\uXXXX` (BMP + surrogate
//!   pairs).
//! * The writer emits UTF-8 and escapes control characters.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Object keys are kept sorted (BTreeMap) so output is
/// deterministic — important for golden-file tests.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

/// Parse error with byte offset and a short message.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct JsonError {
    pub offset: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.offset, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    // ---------- constructors ----------

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(
            pairs
                .into_iter()
                .map(|(k, v)| (k.to_string(), v))
                .collect(),
        )
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    pub fn num(n: impl Into<f64>) -> Json {
        Json::Num(n.into())
    }

    pub fn arr(items: Vec<Json>) -> Json {
        Json::Arr(items)
    }

    // ---------- accessors ----------

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().and_then(|n| {
            if n >= 0.0 && n.fract() == 0.0 {
                Some(n as usize)
            } else {
                None
            }
        })
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// `obj["key"]` lookup; `Json::Null` if absent or not an object.
    pub fn get(&self, key: &str) -> &Json {
        static NULL: Json = Json::Null;
        match self {
            Json::Obj(o) => o.get(key).unwrap_or(&NULL),
            _ => &NULL,
        }
    }

    /// Path lookup: `j.at(&["a", "b"])` == `j["a"]["b"]`.
    pub fn at(&self, path: &[&str]) -> &Json {
        let mut cur = self;
        for k in path {
            cur = cur.get(k);
        }
        cur
    }

    // ---------- writer ----------

    /// Compact single-line encoding.
    pub fn to_string_compact(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, None, 0);
        out
    }

    /// Pretty encoding with 2-space indent.
    pub fn to_string_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, Some(2), 0);
        out.push('\n');
        out
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(n) => write_num(out, *n),
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(items) => {
                if items.is_empty() {
                    out.push_str("[]");
                    return;
                }
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    item.write(out, indent, depth + 1);
                }
                newline_indent(out, indent, depth);
                out.push(']');
            }
            Json::Obj(map) => {
                if map.is_empty() {
                    out.push_str("{}");
                    return;
                }
                out.push('{');
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                newline_indent(out, indent, depth);
                out.push('}');
            }
        }
    }

    // ---------- parser ----------

    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser {
            bytes: text.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters after value"));
        }
        Ok(v)
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

fn write_num(out: &mut String, n: f64) {
    if n.is_nan() || n.is_infinite() {
        // JSON has no NaN/Inf; clamp to null like most tolerant writers.
        out.push_str("null");
    } else if n.fract() == 0.0 && n.abs() < 9.007_199_254_740_992e15 {
        out.push_str(&format!("{}", n as i64));
    } else {
        out.push_str(&format!("{n}"));
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            '\u{08}' => out.push_str("\\b"),
            '\u{0C}' => out.push_str("\\f"),
            c if (c as u32) < 0x20 => {
                out.push_str(&format!("\\u{:04x}", c as u32));
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError {
            offset: self.pos,
            msg: msg.to_string(),
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(b'-' | b'0'..=b'9') => self.number(),
            _ => Err(self.err("expected a JSON value")),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(map)),
                _ => return Err(self.err("expected ',' or '}' in object")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(items)),
                _ => return Err(self.err("expected ',' or ']' in array")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'n') => out.push('\n'),
                    Some(b'r') => out.push('\r'),
                    Some(b't') => out.push('\t'),
                    Some(b'b') => out.push('\u{08}'),
                    Some(b'f') => out.push('\u{0C}'),
                    Some(b'u') => {
                        let hi = self.hex4()?;
                        let cp = if (0xD800..0xDC00).contains(&hi) {
                            // surrogate pair
                            if self.bump() != Some(b'\\') || self.bump() != Some(b'u') {
                                return Err(self.err("unpaired surrogate"));
                            }
                            let lo = self.hex4()?;
                            if !(0xDC00..0xE000).contains(&lo) {
                                return Err(self.err("invalid low surrogate"));
                            }
                            0x10000 + (((hi - 0xD800) as u32) << 10) + (lo - 0xDC00) as u32
                        } else {
                            hi as u32
                        };
                        out.push(
                            char::from_u32(cp)
                                .ok_or_else(|| self.err("invalid unicode escape"))?,
                        );
                    }
                    _ => return Err(self.err("invalid escape")),
                },
                Some(b) if b < 0x20 => return Err(self.err("control char in string")),
                Some(b) => {
                    // Reconstruct UTF-8 multibyte sequences verbatim.
                    let start = self.pos - 1;
                    let len = utf8_len(b);
                    let end = start + len;
                    if end > self.bytes.len() {
                        return Err(self.err("truncated utf-8"));
                    }
                    let s = std::str::from_utf8(&self.bytes[start..end])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    out.push_str(s);
                    self.pos = end;
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u16, JsonError> {
        let mut v: u16 = 0;
        for _ in 0..4 {
            let b = self.bump().ok_or_else(|| self.err("truncated \\u escape"))?;
            let d = (b as char)
                .to_digit(16)
                .ok_or_else(|| self.err("bad hex digit"))?;
            v = (v << 4) | d as u16;
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(b'0'..=b'9')) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("invalid number"))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("false").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x"}"#).unwrap();
        assert_eq!(j.get("c").as_str(), Some("x"));
        assert_eq!(j.get("a").as_arr().unwrap().len(), 3);
        assert_eq!(j.get("a").as_arr().unwrap()[2].get("b"), &Json::Null);
    }

    #[test]
    fn parse_escapes() {
        let j = Json::parse(r#""a\nb\t\"q\" é 😀""#).unwrap();
        assert_eq!(j.as_str().unwrap(), "a\nb\t\"q\" é 😀");
    }

    #[test]
    fn roundtrip_identity() {
        let src = r#"{"arr":[1,2.5,-3],"nested":{"x":true,"y":null},"s":"héllo\n"}"#;
        let j = Json::parse(src).unwrap();
        let printed = j.to_string_compact();
        let j2 = Json::parse(&printed).unwrap();
        assert_eq!(j, j2);
    }

    #[test]
    fn pretty_is_parseable() {
        let j = Json::obj(vec![
            ("name", Json::str("lenet")),
            ("devices", Json::arr(vec![Json::num(1.0), Json::num(2.0)])),
        ]);
        let pretty = j.to_string_pretty();
        assert_eq!(Json::parse(&pretty).unwrap(), j);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("\"unterminated").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("nul").is_err());
    }

    #[test]
    fn integer_format_has_no_decimal_point() {
        assert_eq!(Json::Num(3.0).to_string_compact(), "3");
        assert_eq!(Json::Num(3.25).to_string_compact(), "3.25");
    }

    #[test]
    fn path_lookup() {
        let j = Json::parse(r#"{"a":{"b":{"c":7}}}"#).unwrap();
        assert_eq!(j.at(&["a", "b", "c"]).as_usize(), Some(7));
        assert_eq!(j.at(&["a", "zz", "c"]), &Json::Null);
    }

    #[test]
    fn obj_keys_sorted_deterministically() {
        let j = Json::parse(r#"{"z":1,"a":2}"#).unwrap();
        assert_eq!(j.to_string_compact(), r#"{"a":2,"z":1}"#);
    }
}
