//! ASCII table rendering for CLI reports and bench output.
//!
//! The bench harnesses print the same rows the paper's figures plot; this
//! module gives them a uniform, aligned presentation.

/// Column alignment.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Align {
    Left,
    Right,
}

/// A simple text table builder.
#[derive(Debug, Clone, Default)]
pub struct Table {
    header: Vec<String>,
    aligns: Vec<Align>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(header: &[&str]) -> Self {
        Self {
            aligns: header
                .iter()
                .enumerate()
                .map(|(i, _)| if i == 0 { Align::Left } else { Align::Right })
                .collect(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    /// Override alignments (defaults: first column left, rest right).
    pub fn aligns(mut self, aligns: &[Align]) -> Self {
        assert_eq!(aligns.len(), self.header.len());
        self.aligns = aligns.to_vec();
        self
    }

    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        assert_eq!(
            cells.len(),
            self.header.len(),
            "row width must match header"
        );
        self.rows.push(cells);
        self
    }

    pub fn row_strs(&mut self, cells: &[&str]) -> &mut Self {
        self.row(cells.iter().map(|s| s.to_string()).collect())
    }

    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    pub fn render(&self) -> String {
        let ncol = self.header.len();
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.chars().count()).collect();
        for row in &self.rows {
            for (i, cell) in row.iter().enumerate() {
                widths[i] = widths[i].max(cell.chars().count());
            }
        }
        let mut out = String::new();
        let sep = |out: &mut String| {
            out.push('+');
            for w in &widths {
                out.push_str(&"-".repeat(w + 2));
                out.push('+');
            }
            out.push('\n');
        };
        let emit_row = |out: &mut String, cells: &[String], aligns: &[Align]| {
            out.push('|');
            for ((cell, w), a) in cells.iter().zip(&widths).zip(aligns) {
                let pad = w - cell.chars().count();
                match a {
                    Align::Left => {
                        out.push(' ');
                        out.push_str(cell);
                        out.push_str(&" ".repeat(pad + 1));
                    }
                    Align::Right => {
                        out.push_str(&" ".repeat(pad + 1));
                        out.push_str(cell);
                        out.push(' ');
                    }
                }
                out.push('|');
            }
            out.push('\n');
        };
        sep(&mut out);
        emit_row(&mut out, &self.header, &vec![Align::Left; ncol]);
        sep(&mut out);
        for row in &self.rows {
            emit_row(&mut out, row, &self.aligns);
        }
        sep(&mut out);
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new(&["model", "latency", "mem"]);
        t.row_strs(&["lenet", "1.2 ms", "3 KiB"]);
        t.row_strs(&["vgg19", "250.0 ms", "120 MiB"]);
        let s = t.render();
        assert!(s.contains("| model "));
        assert!(s.contains("lenet"));
        // every line same width
        let lens: Vec<usize> = s.lines().map(|l| l.chars().count()).collect();
        assert!(lens.windows(2).all(|w| w[0] == w[1]), "{s}");
    }

    #[test]
    #[should_panic]
    fn row_width_checked() {
        let mut t = Table::new(&["a", "b"]);
        t.row_strs(&["only-one"]);
    }

    #[test]
    fn unicode_width_by_chars() {
        let mut t = Table::new(&["x"]);
        t.row_strs(&["µs-wide"]);
        let s = t.render();
        let lens: Vec<usize> = s.lines().map(|l| l.chars().count()).collect();
        assert!(lens.windows(2).all(|w| w[0] == w[1]), "{s}");
    }
}
