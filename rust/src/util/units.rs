//! Human-readable formatting for the quantities the cost model trades in:
//! bytes, FLOPs, seconds, and rates.

/// Format a byte count with binary prefixes ("12.3 MiB").
pub fn fmt_bytes(bytes: u64) -> String {
    const UNITS: [&str; 6] = ["B", "KiB", "MiB", "GiB", "TiB", "PiB"];
    let mut v = bytes as f64;
    let mut u = 0;
    while v >= 1024.0 && u < UNITS.len() - 1 {
        v /= 1024.0;
        u += 1;
    }
    if u == 0 {
        format!("{bytes} B")
    } else {
        format!("{v:.2} {}", UNITS[u])
    }
}

/// Format a FLOP count with SI prefixes ("1.23 GFLOP").
pub fn fmt_flops(flops: f64) -> String {
    const UNITS: [&str; 5] = ["FLOP", "KFLOP", "MFLOP", "GFLOP", "TFLOP"];
    let mut v = flops;
    let mut u = 0;
    while v >= 1000.0 && u < UNITS.len() - 1 {
        v /= 1000.0;
        u += 1;
    }
    format!("{v:.2} {}", UNITS[u])
}

/// Format seconds adaptively ("1.23 s", "4.56 ms", "7.89 µs").
pub fn fmt_secs(secs: f64) -> String {
    let a = secs.abs();
    if a >= 1.0 {
        format!("{secs:.3} s")
    } else if a >= 1e-3 {
        format!("{:.3} ms", secs * 1e3)
    } else if a >= 1e-6 {
        format!("{:.3} µs", secs * 1e6)
    } else {
        format!("{:.1} ns", secs * 1e9)
    }
}

/// Format a rate per second ("3.21 K/s").
pub fn fmt_rate(per_sec: f64) -> String {
    const UNITS: [&str; 4] = ["", "K", "M", "G"];
    let mut v = per_sec;
    let mut u = 0;
    while v >= 1000.0 && u < UNITS.len() - 1 {
        v /= 1000.0;
        u += 1;
    }
    format!("{v:.2} {}/s", UNITS[u])
}

/// Percentage delta of `new` relative to `base`: negative = improvement
/// (smaller is better for latency/memory).
pub fn pct_delta(base: f64, new: f64) -> f64 {
    if base == 0.0 {
        0.0
    } else {
        (new - base) / base * 100.0
    }
}

/// Saving of `new` vs `base` in percent (positive = `new` is smaller).
pub fn pct_saving(base: f64, new: f64) -> f64 {
    -pct_delta(base, new)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bytes_prefixes() {
        assert_eq!(fmt_bytes(512), "512 B");
        assert_eq!(fmt_bytes(2048), "2.00 KiB");
        assert_eq!(fmt_bytes(3 * 1024 * 1024), "3.00 MiB");
    }

    #[test]
    fn flops_prefixes() {
        assert_eq!(fmt_flops(500.0), "500.00 FLOP");
        assert_eq!(fmt_flops(2.5e9), "2.50 GFLOP");
    }

    #[test]
    fn secs_adaptive() {
        assert_eq!(fmt_secs(1.5), "1.500 s");
        assert_eq!(fmt_secs(0.0023), "2.300 ms");
        assert_eq!(fmt_secs(4.2e-6), "4.200 µs");
        assert_eq!(fmt_secs(3.0e-9), "3.0 ns");
    }

    #[test]
    fn savings() {
        assert!((pct_saving(10.0, 8.0) - 20.0).abs() < 1e-12);
        assert!((pct_delta(10.0, 12.0) - 20.0).abs() < 1e-12);
        assert_eq!(pct_delta(0.0, 5.0), 0.0);
    }
}
