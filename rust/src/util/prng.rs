//! Deterministic pseudo-random number generation, mirrored bit-for-bit by
//! `python/compile/weights.py`.
//!
//! The cooperative-inference runtime needs weights that are *identical* on
//! the python (AOT/export) side and the rust (coordinator/executor) side so
//! that distributed execution can be checked numerically against the
//! centralized model. Both sides implement the same SplitMix64 stream and
//! the same `f32` mapping, using only integer arithmetic plus one final
//! float division — which is exactly reproducible across languages.
//!
//! Streams are keyed by a stable FNV-1a hash of a string name (e.g.
//! `"lenet/conv1/w"`), so adding tensors never perturbs existing ones.

/// FNV-1a 64-bit hash of a byte string. Stable across platforms/languages.
pub fn fnv1a(name: &str) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for b in name.as_bytes() {
        h ^= *b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// SplitMix64: tiny, high-quality 64-bit PRNG with a trivially portable
/// integer-only implementation (Vigna, 2015).
#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    /// Stream keyed by a stable string name (FNV-1a of the name is the seed).
    pub fn from_name(name: &str) -> Self {
        Self::new(fnv1a(name))
    }

    /// Next raw 64-bit output.
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }

    /// Uniform in [0, 1): top 24 bits -> f32 division by 2^24.
    /// 24 bits keeps the mapping exact in f32 on both languages.
    pub fn next_f32(&mut self) -> f32 {
        let bits = (self.next_u64() >> 40) as u32; // top 24 bits
        bits as f32 / 16777216.0f32
    }

    /// Uniform in [-scale, scale).
    pub fn next_symmetric(&mut self, scale: f32) -> f32 {
        (self.next_f32() * 2.0 - 1.0) * scale
    }

    /// Uniform u64 in [0, bound) by simple modulo (bias is irrelevant for
    /// test-data generation; NOT for cryptography).
    pub fn next_below(&mut self, bound: u64) -> u64 {
        debug_assert!(bound > 0);
        self.next_u64() % bound
    }

    /// Uniform usize in [lo, hi] inclusive.
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(lo <= hi);
        lo + self.next_below((hi - lo + 1) as u64) as usize
    }

    /// Fill a buffer with symmetric uniform values (the weight initializer).
    pub fn fill_symmetric(&mut self, out: &mut [f32], scale: f32) {
        for v in out.iter_mut() {
            *v = self.next_symmetric(scale);
        }
    }
}

/// Generate a named weight tensor: `n` values in [-scale, scale), seeded by
/// the FNV-1a hash of `name`. Mirrored by `weights.py::named_tensor`.
pub fn named_tensor(name: &str, n: usize, scale: f32) -> Vec<f32> {
    let mut rng = SplitMix64::from_name(name);
    let mut out = vec![0.0f32; n];
    rng.fill_symmetric(&mut out, scale);
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fnv1a_known_vectors() {
        // Standard FNV-1a test vectors.
        assert_eq!(fnv1a(""), 0xcbf29ce484222325);
        assert_eq!(fnv1a("a"), 0xaf63dc4c8601ec8c);
        assert_eq!(fnv1a("foobar"), 0x85944171f73967e8);
    }

    #[test]
    fn splitmix_reference_sequence() {
        // Reference outputs for seed 0 (cross-checked against the published
        // SplitMix64 reference implementation and weights.py).
        let mut r = SplitMix64::new(0);
        assert_eq!(r.next_u64(), 0xE220A8397B1DCDAF);
        assert_eq!(r.next_u64(), 0x6E789E6AA1B965F4);
        assert_eq!(r.next_u64(), 0x06C45D188009454F);
    }

    #[test]
    fn f32_mapping_in_unit_interval() {
        let mut r = SplitMix64::new(12345);
        for _ in 0..10_000 {
            let v = r.next_f32();
            assert!((0.0..1.0).contains(&v), "{v}");
        }
    }

    #[test]
    fn symmetric_bounds() {
        let mut r = SplitMix64::new(7);
        for _ in 0..10_000 {
            let v = r.next_symmetric(0.5);
            assert!((-0.5..0.5).contains(&v), "{v}");
        }
    }

    #[test]
    fn named_tensor_deterministic_and_name_keyed() {
        let a = named_tensor("lenet/conv1/w", 16, 0.1);
        let b = named_tensor("lenet/conv1/w", 16, 0.1);
        let c = named_tensor("lenet/conv2/w", 16, 0.1);
        assert_eq!(a, b);
        assert_ne!(a, c);
    }

    #[test]
    fn golden_values_match_python() {
        // Golden values asserted on both sides; see
        // python/tests/test_weights.py::test_golden_cross_language.
        let v = named_tensor("golden", 4, 1.0);
        let mut r = SplitMix64::from_name("golden");
        let expect: Vec<f32> = (0..4).map(|_| r.next_symmetric(1.0)).collect();
        assert_eq!(v, expect);
        // Literal values frozen here so an accidental algorithm change fails
        // loudly even without the python side present.
        let frozen = [0.32074094, 0.9703958, -0.4739381, 0.18444812];
        for (got, want) in v.iter().zip(frozen.iter()) {
            assert!(
                (got - want).abs() < 1e-7,
                "golden mismatch: got {got}, want {want}"
            );
        }
    }

    #[test]
    fn range_inclusive() {
        let mut r = SplitMix64::new(3);
        let mut seen_lo = false;
        let mut seen_hi = false;
        for _ in 0..1000 {
            let v = r.range(2, 5);
            assert!((2..=5).contains(&v));
            seen_lo |= v == 2;
            seen_hi |= v == 5;
        }
        assert!(seen_lo && seen_hi);
    }
}
