//! Dependency-free substrates: PRNG (mirrored in python), JSON, unit
//! formatting, and ASCII tables. See DESIGN.md §1 for why these are in-house.

pub mod json;
pub mod prng;
pub mod table;
pub mod units;
