//! The **CoEdge baseline** planner: feature-map H-dimension partitioning
//! for the convolutional front of the network, with workloads proportional
//! to device capability and a minimum-rows rule (Zeng et al., ToN 2020);
//! fully-connected layers are *not* partitioned — per the paper's Fig. 3,
//! the conv activations are broadcast + concatenated ("the activations are
//! concatenated to complete the inference") and every device then runs the
//! whole classifier redundantly.
//!
//! Both properties the paper measures follow directly:
//!  * latency: conv stages cost only neighbour halo exchanges (cheap), but
//!    the FC phase gains nothing from the cluster (replicated = serial
//!    time) after paying one AllGather;
//!  * memory (Fig. 5): conv weights are fully replicated on every device
//!    (row shards compute *all* channels of their rows) and every device
//!    holds every FC weight — the worst peak memory of the three
//!    strategies.

use super::plan::{CommStep, Layout, Plan, SliceKind, StagePlan, Strategy};
use super::rows::halo_xfers;
use super::split::{proportional_split_min, ranges};
use crate::device::Cluster;
use crate::model::{Model, OpKind};

/// Minimum rows a device must receive to participate in a row-partitioned
/// stage (CoEdge's anti-sliver rule).
pub const MIN_ROWS: usize = 2;

/// Root device for the serial FC phase and output assembly.
pub const ROOT: usize = 0;

/// Build the CoEdge plan.
pub fn plan_coedge(model: &Model, cluster: &Cluster) -> Plan {
    let m = cluster.m();
    let shares = cluster.compute_shares();
    let mut stages = Vec::new();

    // Row ranges (over the *output* of the previous stage) owned per
    // device, or None once the activation lives on the root.
    let mut prev_rows: Option<Vec<(usize, usize)>> = None;
    let mut prev_stage: Option<crate::model::Stage> = None;
    let mut at_root = false;

    for &stage in model.stages() {
        let op = &model.ops[stage.op_idx];
        match op.kind {
            OpKind::Conv2d { .. } => {
                // Row ranges are defined over the stage's *spatial* output
                // (before any trailing flatten).
                let out = model.stage_spatial_out_shape(stage);
                let counts = proportional_split_min(out.h, &shares, MIN_ROWS.min(out.h));
                let rs = ranges(&counts);
                let slices: Vec<SliceKind> = rs
                    .iter()
                    .map(|&(start, count)| {
                        if count == 0 {
                            SliceKind::Idle
                        } else {
                            SliceKind::Rows { start, count }
                        }
                    })
                    .collect();

                let pre_comm = match (&prev_rows, at_root) {
                    // First conv: input rows are pre-distributed with the
                    // halos they need (input staging is outside the
                    // measured inference path for every strategy).
                    (None, false) => CommStep::None,
                    // Interior conv: exchange halo rows with neighbours.
                    (Some(owned), false) => {
                        let x = halo_xfers(model, stage, &rs, owned);
                        if x.is_empty() {
                            CommStep::None
                        } else {
                            CommStep::HaloExchange { xfers: x }
                        }
                    }
                    // Activation is on the root (does not happen for the
                    // paper's chains — FCs come last — but keep it total).
                    (_, true) => {
                        let bytes = model.in_shape(stage.op_idx).bytes();
                        CommStep::Broadcast { root: ROOT, bytes }
                    }
                };
                at_root = false;
                stages.push(StagePlan {
                    stage,
                    pre_comm,
                    slices,
                    out_layout: Layout::RowShard(rs.clone()),
                });
                // Input rows owned at the *next* stage = output rows here.
                prev_rows = Some(rs);
                prev_stage = Some(stage);
            }
            OpKind::Dense { .. } => {
                // FC is unpartitioned: every device holds the concatenated
                // activation and evaluates the classifier in full.
                let slices = vec![SliceKind::Replicate; m];
                let pre_comm = if at_root {
                    CommStep::None // already replicated from the last FC
                } else {
                    // AllGather the row shards of the previous stage output.
                    let (owned, pstage) = (
                        prev_rows.as_ref().expect("fc after conv"),
                        prev_stage.expect("fc after conv"),
                    );
                    let out = model.stage_spatial_out_shape(pstage);
                    let row_bytes = (out.elems() / out.h * 4) as u64;
                    CommStep::AllGather {
                        bytes_per_dev: owned.iter().map(|&(_, c)| c as u64 * row_bytes).collect(),
                    }
                };
                at_root = true; // activation now replicated; no more comm
                stages.push(StagePlan {
                    stage,
                    pre_comm,
                    slices,
                    out_layout: Layout::Replicated,
                });
                prev_rows = None;
                prev_stage = Some(stage);
            }
            _ => unreachable!("stage heads are weighted"),
        }
    }

    // Output is already replicated after the FC phase.
    let final_comm = if at_root {
        CommStep::None
    } else {
        let (owned, pstage) = (prev_rows.as_ref().unwrap(), prev_stage.unwrap());
        let out = model.stage_spatial_out_shape(pstage);
        let row_bytes = (out.elems() / out.h * 4) as u64;
        CommStep::Gather {
            root: ROOT,
            bytes_per_dev: owned.iter().map(|&(_, c)| c as u64 * row_bytes).collect(),
        }
    };

    Plan {
        model_name: model.name.clone(),
        strategy: Strategy::CoEdge,
        m,
        stages,
        final_comm,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::profiles;
    use crate::model::zoo;

    #[test]
    fn plan_is_valid_for_all_models() {
        let cluster = profiles::paper_default();
        for m in zoo::all_models() {
            let p = plan_coedge(&m, &cluster);
            p.validate(&m).unwrap();
        }
    }

    #[test]
    fn fc_stages_replicate_everywhere() {
        let model = zoo::alexnet();
        let p = plan_coedge(&model, &profiles::paper_default());
        let fc_stages: Vec<_> = p
            .stages
            .iter()
            .filter(|s| model.ops[s.stage.op_idx].kind_tag() == "fc")
            .collect();
        assert_eq!(fc_stages.len(), 3);
        for s in fc_stages {
            assert!(s.slices.iter().all(|x| *x == SliceKind::Replicate));
        }
    }

    #[test]
    fn single_allgather_then_no_more_comm() {
        let model = zoo::vgg11();
        let p = plan_coedge(&model, &profiles::paper_default());
        let mut seen_gather = 0;
        let mut fc_seen = false;
        for s in &p.stages {
            let is_fc = model.ops[s.stage.op_idx].kind_tag() == "fc";
            if is_fc {
                if !fc_seen {
                    assert!(matches!(s.pre_comm, CommStep::AllGather { .. }));
                    seen_gather += 1;
                } else {
                    assert!(matches!(s.pre_comm, CommStep::None));
                }
                fc_seen = true;
            }
        }
        assert_eq!(seen_gather, 1);
        assert!(matches!(p.final_comm, CommStep::None));
    }

    #[test]
    fn conv_stages_only_halo() {
        let model = zoo::vgg11();
        let p = plan_coedge(&model, &profiles::paper_default());
        for s in &p.stages {
            if model.ops[s.stage.op_idx].kind_tag() == "conv" {
                assert!(
                    matches!(s.pre_comm, CommStep::None | CommStep::HaloExchange { .. }),
                    "conv stage {:?} has {:?}",
                    s.stage,
                    s.pre_comm.tag()
                );
            }
        }
    }

    #[test]
    fn halo_is_neighbour_local_and_small() {
        let model = zoo::vgg11();
        let cluster = profiles::paper_default();
        let p = plan_coedge(&model, &cluster);
        for s in &p.stages {
            if let CommStep::HaloExchange { xfers } = &s.pre_comm {
                let in_bytes = model.in_shape(s.stage.op_idx).bytes();
                for &(f, t, b) in xfers {
                    assert!(f != t);
                    // halo is a thin sliver of the activation
                    assert!(b * 4 < in_bytes, "halo {b} vs act {in_bytes}");
                }
            }
        }
    }

    #[test]
    fn min_rows_drops_slow_sliver_devices() {
        // A very skewed cluster on a small feature map: the slow device
        // gets nothing rather than a sub-minimum sliver.
        use crate::device::{Cluster, Device};
        let c = Cluster::new(
            vec![
                Device::new(10e9, 1 << 30),
                Device::new(10e9, 1 << 30),
                Device::new(0.1e9, 1 << 30),
            ],
            12.5e6,
            1e-3,
        );
        let model = zoo::lenet();
        let p = plan_coedge(&model, &c);
        p.validate(&model).unwrap();
        // conv2 output is 5 rows; slowest device should be idle there.
        let s = &p.stages[1];
        assert_eq!(s.slices[2].count(), 0);
    }
}
