//! Row-range arithmetic for feature-map (H) partitioning.
//!
//! CoEdge-style partitioning slices the *output* rows of each stage; the
//! rows of the stage *input* a device must hold follow from the receptive
//! field of the stage's ops. Walking the stage backwards (pool ← conv)
//! yields the exact input interval, from which halo-exchange volumes are
//! derived: the part of the interval owned by a row-neighbour device is
//! the halo that has to move.

use crate::model::graph::Stage;
use crate::model::{Model, OpKind};

/// Input rows (unclamped, may extend into padding) required to compute
/// output rows `[a, b)` of `stage`. Returns a signed interval `[lo, hi)`.
pub fn input_rows_needed(model: &Model, stage: Stage, a: usize, b: usize) -> (isize, isize) {
    let mut lo = a as isize;
    let mut hi = b as isize;
    // walk backwards through the stage's ops
    for idx in (stage.op_idx..stage.tail_end).rev() {
        match model.ops[idx].kind {
            OpKind::MaxPool { k, stride } => {
                hi = (hi - 1) * stride as isize + k as isize;
                lo *= stride as isize;
            }
            OpKind::Conv2d {
                k_h, stride, pad, ..
            } => {
                hi = (hi - 1) * stride as isize + k_h as isize - pad as isize;
                lo = lo * stride as isize - pad as isize;
            }
            OpKind::Relu => {}
            // Flatten is a pure re-view: row ranges are defined over the
            // spatial output (before flatten), so it is the identity here.
            OpKind::Flatten => {}
            OpKind::Dense { .. } => {
                panic!("row partitioning through {:?}", model.ops[idx].kind)
            }
        }
    }
    (lo, hi)
}

/// Same, clamped to the valid input rows `[0, h_in)` (padding rows are
/// materialized locally as zeros, they never travel).
pub fn input_rows_needed_clamped(
    model: &Model,
    stage: Stage,
    a: usize,
    b: usize,
) -> (usize, usize) {
    let h_in = model.in_shape(stage.op_idx).h;
    let (lo, hi) = input_rows_needed(model, stage, a, b);
    (
        lo.clamp(0, h_in as isize) as usize,
        hi.clamp(0, h_in as isize) as usize,
    )
}

/// A halo transfer with full row detail (consumed by the executor, which
/// must know *which* input rows move, not just how many bytes).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct HaloXfer {
    pub from: usize,
    pub to: usize,
    /// Input rows `[row_start, row_start + row_count)` of the stage input.
    pub row_start: usize,
    pub row_count: usize,
}

/// Detailed halo transfers needed before `stage` runs row-partitioned with
/// output ranges `out_ranges`, when the stage input is row-owned according
/// to `owned_in_ranges` (both per device, `(start, count)`).
pub fn halo_plan(
    model: &Model,
    stage: Stage,
    out_ranges: &[(usize, usize)],
    owned_in_ranges: &[(usize, usize)],
) -> Vec<HaloXfer> {
    let mut xfers = Vec::new();
    for (j, &(a, cnt)) in out_ranges.iter().enumerate() {
        if cnt == 0 {
            continue;
        }
        let (lo, hi) = input_rows_needed_clamped(model, stage, a, a + cnt);
        for (j2, &(o_s, o_c)) in owned_in_ranges.iter().enumerate() {
            if j2 == j || o_c == 0 {
                continue;
            }
            let ov_lo = lo.max(o_s);
            let ov_hi = hi.min(o_s + o_c);
            if ov_hi > ov_lo {
                xfers.push(HaloXfer {
                    from: j2,
                    to: j,
                    row_start: ov_lo,
                    row_count: ov_hi - ov_lo,
                });
            }
        }
    }
    xfers
}

/// Byte-level view of [`halo_plan`] — what the planners/cost model price.
pub fn halo_xfers(
    model: &Model,
    stage: Stage,
    out_ranges: &[(usize, usize)],
    owned_in_ranges: &[(usize, usize)],
) -> Vec<(usize, usize, u64)> {
    let in_shape = model.in_shape(stage.op_idx);
    let row_bytes = (in_shape.c * in_shape.w * 4) as u64;
    halo_plan(model, stage, out_ranges, owned_in_ranges)
        .into_iter()
        .map(|h| (h.from, h.to, h.row_count as u64 * row_bytes))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::zoo;

    #[test]
    fn conv_receptive_field() {
        // LeNet stage 0: conv1 (5x5, pad 2) + pool1 (2x2 s2).
        let m = zoo::lenet();
        let st = m.stages()[0];
        // pool output row 0 needs conv rows [0,2), which (5x5 conv, pad 2,
        // stride 1) need input rows [0*1-2, 1+5-2) = [-2, 4) -> clamped.
        let (lo, hi) = input_rows_needed(&m, st, 0, 1);
        assert_eq!((lo, hi), (-2, 4));
        let (lo, hi) = input_rows_needed_clamped(&m, st, 0, 1);
        assert_eq!((lo, hi), (0, 4));
    }

    #[test]
    fn pure_conv_stage() {
        // VGG conv stage without pool: 3x3 pad 1 -> rows [a-1, b+1)
        let m = zoo::vgg11();
        let stages = m.stages();
        // stage 2 = conv3_1 (no pool behind it)
        let st = stages
            .iter()
            .find(|s| m.ops[s.op_idx].name == "conv3_1")
            .copied()
            .unwrap();
        let (lo, hi) = input_rows_needed(&m, st, 10, 20);
        assert_eq!((lo, hi), (9, 21));
    }

    #[test]
    fn halo_volume_between_neighbours() {
        let m = zoo::vgg11();
        let st = m
            .stages()
            .iter()
            .find(|s| m.ops[s.op_idx].name == "conv3_1")
            .copied()
            .unwrap();
        let in_shape = m.in_shape(st.op_idx); // 128 x 56 x 56
        assert_eq!((in_shape.c, in_shape.h, in_shape.w), (128, 56, 56));
        // 3 devices, even rows: each needs 1 halo row from each neighbour.
        let out = [(0usize, 19usize), (19, 19), (38, 18)];
        let owned = out; // conv3_1 preserves H (pad 1), input owned = same split
        let x = halo_xfers(&m, st, &out, &owned);
        let row_bytes = 128 * 56 * 4;
        // dev0 needs row 19 from dev1; dev1 needs row 18 from dev0 and row
        // 38 from dev2; dev2 needs row 37 from dev1 -> 4 transfers.
        assert_eq!(x.len(), 4);
        assert!(x.iter().all(|&(_, _, b)| b == row_bytes));
    }

    #[test]
    fn no_halo_when_pool_aligned() {
        // LeNet stage 0 with pool: output rows tile 14; device 1's input
        // needs extend into device 0's rows (5x5 conv), so halos exist.
        let m = zoo::lenet();
        let st = m.stages()[0];
        let out = [(0usize, 5usize), (5, 5), (10, 4)];
        let owned = [(0usize, 10usize), (10, 10), (20, 8)];
        let x = halo_xfers(&m, st, &out, &owned);
        assert!(!x.is_empty());
        // all transfers are between row-neighbours
        for &(f, t, _) in &x {
            assert_eq!((f as isize - t as isize).abs(), 1, "{f}->{t}");
        }
    }
}
