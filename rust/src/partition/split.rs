//! Proportional integer allocation — the numeric kernel behind the
//! partition-size constraints (paper eqs. 3–5): partition a dimension of
//! size `n` across `m` devices proportionally to their compute shares so
//! the parts tile the dimension exactly (`Σ parts == n`, every part ≥ 0).
//!
//! Uses the largest-remainder (Hamilton) method: floor the real quotas,
//! then hand the leftover units to the largest fractional remainders
//! (ties broken by device index, so allocation is deterministic).

/// Split `n` units proportionally to `shares` (need not be normalized).
/// Returns per-device counts summing to exactly `n`.
pub fn proportional_split(n: usize, shares: &[f64]) -> Vec<usize> {
    assert!(!shares.is_empty(), "need at least one share");
    assert!(shares.iter().all(|s| *s >= 0.0), "shares must be >= 0");
    let total: f64 = shares.iter().sum();
    assert!(total > 0.0, "shares must not all be zero");

    let quotas: Vec<f64> = shares.iter().map(|s| n as f64 * s / total).collect();
    let mut counts: Vec<usize> = quotas.iter().map(|q| q.floor() as usize).collect();
    let assigned: usize = counts.iter().sum();
    let mut leftover = n - assigned;

    // Largest fractional remainder first; ties by lower index.
    let mut order: Vec<usize> = (0..shares.len()).collect();
    order.sort_by(|&a, &b| {
        let fa = quotas[a] - quotas[a].floor();
        let fb = quotas[b] - quotas[b].floor();
        fb.partial_cmp(&fa)
            .unwrap_or(std::cmp::Ordering::Equal)
            .then(a.cmp(&b))
    });
    for &i in order.iter().cycle() {
        if leftover == 0 {
            break;
        }
        counts[i] += 1;
        leftover -= 1;
    }
    counts
}

/// Split with a per-part minimum for parts that receive anything at all:
/// parts smaller than `min_part` are zeroed and their units redistributed
/// (CoEdge's "minimum number of rows" rule, which avoids slivers whose halo
/// overhead exceeds their compute value).
pub fn proportional_split_min(n: usize, shares: &[f64], min_part: usize) -> Vec<usize> {
    let mut active: Vec<bool> = vec![true; shares.len()];
    loop {
        let eff: Vec<f64> = shares
            .iter()
            .zip(&active)
            .map(|(s, a)| if *a { *s } else { 0.0 })
            .collect();
        if eff.iter().sum::<f64>() <= 0.0 {
            // nothing active: give everything to the largest share
            let mut counts = vec![0; shares.len()];
            let best = (0..shares.len())
                .max_by(|&a, &b| shares[a].partial_cmp(&shares[b]).unwrap())
                .unwrap();
            counts[best] = n;
            return counts;
        }
        let counts = proportional_split(n, &eff);
        // find active parts violating the minimum
        if let Some(worst) = (0..counts.len())
            .filter(|&i| active[i] && counts[i] > 0 && counts[i] < min_part)
            .min_by_key(|&i| counts[i])
        {
            active[worst] = false;
            continue;
        }
        // also deactivate zero-count actives so ranges stay contiguous
        for i in 0..counts.len() {
            if counts[i] == 0 {
                active[i] = false;
            }
        }
        return counts;
    }
}

/// Convert counts to contiguous `(start, count)` ranges.
pub fn ranges(counts: &[usize]) -> Vec<(usize, usize)> {
    let mut out = Vec::with_capacity(counts.len());
    let mut start = 0;
    for &c in counts {
        out.push((start, c));
        start += c;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exact_tiling() {
        for n in [0usize, 1, 7, 64, 100, 4096] {
            for shares in [vec![1.0, 1.0, 1.0], vec![2.0, 1.0, 0.5], vec![1.0]] {
                let c = proportional_split(n, &shares);
                assert_eq!(c.iter().sum::<usize>(), n, "n={n} shares={shares:?}");
            }
        }
    }

    #[test]
    fn proportionality() {
        let c = proportional_split(100, &[2.0, 1.0, 1.0]);
        assert_eq!(c, vec![50, 25, 25]);
        let c = proportional_split(10, &[1.0, 1.0, 1.0]);
        assert_eq!(c.iter().sum::<usize>(), 10);
        assert!(c.iter().all(|&x| (3..=4).contains(&x)));
    }

    #[test]
    fn deterministic_tie_break() {
        let a = proportional_split(10, &[1.0, 1.0, 1.0]);
        let b = proportional_split(10, &[1.0, 1.0, 1.0]);
        assert_eq!(a, b);
        assert_eq!(a, vec![4, 3, 3]); // first device wins the tie
    }

    #[test]
    fn min_part_redistributes() {
        // 10 rows over shares (10, 10, 1): naive gives the slow device 0–1
        // rows; with min_part=2 it is dropped entirely.
        let c = proportional_split_min(10, &[10.0, 10.0, 1.0], 2);
        assert_eq!(c.iter().sum::<usize>(), 10);
        assert_eq!(c[2], 0);
        let c = proportional_split_min(9, &[1.0, 1.0, 1.0], 2);
        assert_eq!(c.iter().sum::<usize>(), 9);
        assert!(c.iter().all(|&x| x == 0 || x >= 2));
    }

    #[test]
    fn min_part_degenerate_single_winner() {
        let c = proportional_split_min(1, &[1.0, 2.0, 1.5], 3);
        assert_eq!(c.iter().sum::<usize>(), 1);
        assert_eq!(c[1], 1); // largest share takes all
    }

    #[test]
    fn ranges_contiguous() {
        let r = ranges(&[4, 0, 3]);
        assert_eq!(r, vec![(0, 4), (4, 0), (4, 3)]);
    }

    #[test]
    fn zero_n() {
        assert_eq!(proportional_split(0, &[1.0, 2.0]), vec![0, 0]);
    }
}
