//! The **IOP planner** — the paper's contribution (§3–§4).
//!
//! Given a segmentation `Γ = [γ_1 … γ_k]` (pairs + singles over the model's
//! weighted stages), build the full partition plan:
//!
//!  * `Pair(i)`: stage `i` is split on **OC**, stage `i+1` on **IC** with
//!    channel blocks aligned to stage `i`'s output blocks — the transition
//!    between them is `CommStep::None` (the whole point of IOP). The pair
//!    ends with one reduce(+broadcast) of the partial sums: `2(m-1)`
//!    connections instead of the `2·m(m-1)` two OC layers would pay.
//!  * `Single(i)`: falls back to CoEdge-style partitioning for that stage
//!    (rows for conv, unpartitioned/replicated for FC), exactly as
//!    Algorithm 1 prescribes when pairing doesn't profit.
//!
//! Between segments the planner inserts the cheapest layout transition
//! (locally-satisfiable ones are free; see `Layout`).

use super::coedge::{MIN_ROWS, ROOT};
use super::oc::oc_shard_bytes_all;
use super::plan::{CommStep, Layout, Plan, Segment, SliceKind, StagePlan, Strategy};
use super::rows::halo_xfers;
use super::split::{proportional_split, proportional_split_min, ranges};
use crate::device::Cluster;
use crate::model::{Model, OpKind, Stage};

/// Can stages `a` and `b` (= `a`'s successor) form an IOP pair?
/// Requires channel alignment between `a`'s OC blocks and `b`'s IC blocks:
///  * conv→conv (possibly through pool): `b.c_in == a.c_out`;
///  * conv→fc (through pool/flatten): features scale by `H·W`, blocks stay
///    channel-contiguous;
///  * fc→fc: direct.
pub fn pairable(model: &Model, a: Stage, b: Stage) -> bool {
    let op_a = &model.ops[a.op_idx];
    let op_b = &model.ops[b.op_idx];
    let (Some(a_out), Some(b_in)) = (op_a.c_out(), op_b.c_in()) else {
        return false;
    };
    match op_b.kind {
        OpKind::Conv2d { .. } => b_in == a_out,
        OpKind::Dense { .. } => {
            let feats = model.stage_out_shape(a).elems();
            feats == b_in && feats % a_out == 0
        }
        _ => false,
    }
}

/// Tracks what the activation between segments looks like, with enough
/// context to price/shape transitions.
enum Flow {
    Replicated,
    RowShard {
        ranges: Vec<(usize, usize)>,
        stage: Stage,
    },
    /// Raw (pre-tail) partial sums of `op_idx`, full shape on each device.
    Partial {
        stage: Stage,
    },
}

/// Transition the flow state to "every device holds the full activation".
fn to_replicated(model: &Model, flow: &Flow) -> CommStep {
    match flow {
        Flow::Replicated => CommStep::None,
        Flow::RowShard { ranges, stage } => {
            let out = model.stage_spatial_out_shape(*stage);
            let row_bytes = (out.elems() / out.h * 4) as u64;
            CommStep::AllGather {
                bytes_per_dev: ranges.iter().map(|&(_, c)| c as u64 * row_bytes).collect(),
            }
        }
        Flow::Partial { stage } => CommStep::ReduceBroadcast {
            root: ROOT,
            bytes: model.out_shape(stage.op_idx).bytes(),
        },
    }
}

/// Build the IOP plan for a given segmentation.
pub fn plan_iop_with_segments(model: &Model, cluster: &Cluster, segments: &[Segment]) -> Plan {
    let stages = model.stages();
    super::plan::validate_segments(segments, stages.len()).expect("invalid segmentation");
    let m = cluster.m();
    let shares = cluster.compute_shares();
    let mut out_stages: Vec<StagePlan> = Vec::with_capacity(stages.len());
    let mut flow = Flow::Replicated; // input image replicated

    // Bytes of the activation entering segment boundaries (for RootOnly
    // broadcasts).
    let mut prev_out_bytes: u64 = model.input.bytes();

    for seg in segments {
        match *seg {
            Segment::Pair(i) => {
                let (sa, sb) = (stages[i], stages[i + 1]);
                let op_a = &model.ops[sa.op_idx];
                let op_b = &model.ops[sb.op_idx];
                debug_assert!(pairable(model, sa, sb), "unpairable segment at {i}");

                // --- stage A: OC split ---
                let c_out = op_a.c_out().unwrap();
                let counts = proportional_split(c_out, &shares);
                let rs_a = ranges(&counts);
                let pre_a = patch_broadcast(to_replicated(model, &flow), prev_out_bytes);
                let slices_a: Vec<SliceKind> = rs_a
                    .iter()
                    .map(|&(start, count)| {
                        if count == 0 {
                            SliceKind::Idle
                        } else {
                            SliceKind::Oc { start, count }
                        }
                    })
                    .collect();
                out_stages.push(StagePlan {
                    stage: sa,
                    pre_comm: pre_a,
                    slices: slices_a,
                    out_layout: Layout::OcShard(rs_a.clone()),
                });

                // --- stage B: IC split aligned to A's OC blocks ---
                // conv→conv: same channel units; →fc through flatten:
                // channel blocks scale by the spatial plane size.
                let scale = match op_b.kind {
                    OpKind::Dense { c_in, .. } => c_in / c_out,
                    _ => 1,
                };
                let slices_b: Vec<SliceKind> = rs_a
                    .iter()
                    .map(|&(start, count)| {
                        if count == 0 {
                            SliceKind::Idle
                        } else {
                            SliceKind::Ic {
                                start: start * scale,
                                count: count * scale,
                            }
                        }
                    })
                    .collect();
                out_stages.push(StagePlan {
                    stage: sb,
                    pre_comm: CommStep::None, // the IOP identity transition
                    slices: slices_b,
                    out_layout: Layout::Partial,
                });
                flow = Flow::Partial { stage: sb };
                prev_out_bytes = model.stage_out_shape(sb).bytes();
            }
            Segment::Single(i) => {
                let stage = stages[i];
                let op = &model.ops[stage.op_idx];
                match op.kind {
                    OpKind::Conv2d { .. } => {
                        // CoEdge-style row partitioning.
                        let out = model.stage_spatial_out_shape(stage);
                        let counts = proportional_split_min(out.h, &shares, MIN_ROWS.min(out.h));
                        let rs = ranges(&counts);
                        let pre = match &flow {
                            Flow::Replicated => CommStep::None,
                            Flow::RowShard { ranges: owned, .. } => {
                                let x = halo_xfers(model, stage, &rs, owned);
                                if x.is_empty() {
                                    CommStep::None
                                } else {
                                    CommStep::HaloExchange { xfers: x }
                                }
                            }
                            Flow::Partial { stage: ps } => CommStep::ReduceBroadcast {
                                root: ROOT,
                                bytes: model.out_shape(ps.op_idx).bytes(),
                            },
                        };
                        let slices: Vec<SliceKind> = rs
                            .iter()
                            .map(|&(start, count)| {
                                if count == 0 {
                                    SliceKind::Idle
                                } else {
                                    SliceKind::Rows { start, count }
                                }
                            })
                            .collect();
                        out_stages.push(StagePlan {
                            stage,
                            pre_comm: pre,
                            slices,
                            out_layout: Layout::RowShard(rs.clone()),
                        });
                        flow = Flow::RowShard { ranges: rs, stage };
                    }
                    OpKind::Dense { .. } => {
                        // CoEdge-style fallback: unpartitioned — replicate
                        // the whole FC stage on every device.
                        let pre = patch_broadcast(to_replicated(model, &flow), prev_out_bytes);
                        let slices = vec![SliceKind::Replicate; m];
                        out_stages.push(StagePlan {
                            stage,
                            pre_comm: pre,
                            slices,
                            out_layout: Layout::Replicated,
                        });
                        flow = Flow::Replicated;
                    }
                    _ => unreachable!("stage heads are weighted"),
                }
                prev_out_bytes = model.stage_out_shape(stage).bytes();
            }
        }
    }

    // Assemble the output on the root.
    let final_comm = match &flow {
        Flow::Replicated => CommStep::None,
        Flow::RowShard { ranges: owned, stage } => {
            let out = model.stage_spatial_out_shape(*stage);
            let row_bytes = (out.elems() / out.h * 4) as u64;
            CommStep::Gather {
                root: ROOT,
                bytes_per_dev: owned.iter().map(|&(_, c)| c as u64 * row_bytes).collect(),
            }
        }
        Flow::Partial { stage } => CommStep::ReduceTo {
            root: ROOT,
            bytes: model.out_shape(stage.op_idx).bytes(),
        },
    };

    Plan {
        model_name: model.name.clone(),
        strategy: Strategy::Iop,
        m,
        stages: out_stages,
        final_comm,
    }
}

fn patch_broadcast(step: CommStep, bytes: u64) -> CommStep {
    match step {
        CommStep::Broadcast { root, .. } => CommStep::Broadcast { root, bytes },
        other => other,
    }
}

/// Helper: per-device byte sizes of an OC-sharded stage output (used by
/// tests and the executor).
pub fn oc_out_bytes(model: &Model, stage: Stage, rs: &[(usize, usize)]) -> Vec<u64> {
    oc_shard_bytes_all(model, stage, rs)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::profiles;
    use crate::model::zoo;

    fn all_pairs_segmentation(n: usize) -> Vec<Segment> {
        let mut v = Vec::new();
        let mut i = 0;
        while i + 1 < n {
            v.push(Segment::Pair(i));
            i += 2;
        }
        if i < n {
            v.push(Segment::Single(i));
        }
        v
    }

    #[test]
    fn lenet_pairable_chain() {
        let m = zoo::lenet();
        let st = m.stages();
        // conv1->conv2 (through pool), conv2->fc1 (through pool+flatten),
        // fc1->fc2, fc2->fc3 all pairable
        for i in 0..st.len() - 1 {
            assert!(pairable(&m, st[i], st[i + 1]), "stages {i},{}", i + 1);
        }
    }

    #[test]
    fn pair_has_no_internal_comm() {
        let model = zoo::lenet();
        let cluster = profiles::paper_default();
        let segs = all_pairs_segmentation(model.stages().len());
        let p = plan_iop_with_segments(&model, &cluster, &segs);
        p.validate(&model).unwrap();
        // stage 1 (second of first pair) must have CommStep::None
        assert!(matches!(p.stages[1].pre_comm, CommStep::None));
        // and its slices must be IC
        assert!(p.stages[1]
            .slices
            .iter()
            .all(|s| matches!(s, SliceKind::Ic { .. } | SliceKind::Idle)));
    }

    #[test]
    fn ic_blocks_align_with_oc_blocks() {
        let model = zoo::lenet();
        let cluster = profiles::paper_default();
        let segs = vec![
            Segment::Pair(0), // conv1 OC + conv2 IC
            Segment::Pair(2), // fc1 OC + fc2 IC
            Segment::Single(4),
        ];
        let p = plan_iop_with_segments(&model, &cluster, &segs);
        p.validate(&model).unwrap();
        // conv1 OC over 6 channels; conv2 IC over 6 channels: aligned 1:1
        for (a, b) in p.stages[0].slices.iter().zip(&p.stages[1].slices) {
            if let (SliceKind::Oc { start, count }, SliceKind::Ic { start: s2, count: c2 }) = (a, b)
            {
                assert_eq!((start, count), (s2, c2));
            }
        }
    }

    #[test]
    fn conv_to_fc_pair_scales_blocks_by_plane() {
        let model = zoo::lenet();
        let cluster = profiles::paper_default();
        // pair conv2 (stage 1) with fc1 (stage 2)
        let segs = vec![
            Segment::Single(0),
            Segment::Pair(1),
            Segment::Single(3),
            Segment::Single(4),
        ];
        let p = plan_iop_with_segments(&model, &cluster, &segs);
        p.validate(&model).unwrap();
        // conv2: 16 channels -> fc1: 400 features; scale = 25
        let a = &p.stages[1].slices;
        let b = &p.stages[2].slices;
        for (sa, sb) in a.iter().zip(b) {
            if let (SliceKind::Oc { start, count }, SliceKind::Ic { start: s2, count: c2 }) =
                (sa, sb)
            {
                assert_eq!(*s2, start * 25);
                assert_eq!(*c2, count * 25);
            }
        }
    }

    #[test]
    fn all_singles_matches_coedge_structure() {
        let model = zoo::vgg11();
        let cluster = profiles::paper_default();
        let segs: Vec<Segment> = (0..model.stages().len()).map(Segment::Single).collect();
        let p = plan_iop_with_segments(&model, &cluster, &segs);
        p.validate(&model).unwrap();
        let co = crate::partition::coedge::plan_coedge(&model, &cluster);
        // same slices and comm tags stage by stage
        for (a, b) in p.stages.iter().zip(&co.stages) {
            assert_eq!(a.slices, b.slices);
            assert_eq!(a.pre_comm.tag(), b.pre_comm.tag());
        }
    }

    #[test]
    fn pair_reduces_connections_vs_oc() {
        let model = zoo::lenet();
        let cluster = profiles::paper_default();
        let segs = all_pairs_segmentation(model.stages().len());
        let iop = plan_iop_with_segments(&model, &cluster, &segs);
        let oc = crate::partition::oc::plan_oc(&model, &cluster);
        assert!(
            iop.total_connections() < oc.total_connections(),
            "iop={} oc={}",
            iop.total_connections(),
            oc.total_connections()
        );
    }

    #[test]
    #[should_panic]
    fn invalid_segmentation_panics() {
        let model = zoo::lenet();
        let cluster = profiles::paper_default();
        plan_iop_with_segments(&model, &cluster, &[Segment::Pair(0)]);
    }
}
