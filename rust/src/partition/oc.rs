//! The **OC baseline** planner: every weighted op is partitioned on its
//! output-channel dimension, proportionally to device compute capability
//! (the original two-GPU AlexNet scheme, generalized to m devices).
//!
//! Consequence encoded here: each stage consumes the *full* input (an OC
//! shard of a conv needs every input channel), so every stage must be
//! preceded by an AllGather of the previous stage's shards — `m(m-1)`
//! connections per stage. This is the communication the paper's IOP
//! removes.

use super::plan::{CommStep, Layout, Plan, SliceKind, StagePlan, Strategy};
use super::split::{proportional_split, ranges};
use crate::device::Cluster;
use crate::model::{Model, Stage};

/// Bytes held by device `j` of a stage output sharded on channels:
/// `count` channels of the weighted op's `c_out`, scaled through the
/// passthrough tail (pool shrinks H/W; flatten keeps the block contiguous).
pub fn oc_shard_bytes(model: &Model, stage: Stage, count: usize) -> u64 {
    let out = model.stage_out_shape(stage);
    let c_out = model.ops[stage.op_idx].c_out().expect("weighted stage");
    let elems_per_channel = out.elems() / c_out;
    (count * elems_per_channel * 4) as u64
}

/// Per-device shard bytes for a whole channel tiling of a stage output.
pub fn oc_shard_bytes_all(model: &Model, stage: Stage, rs: &[(usize, usize)]) -> Vec<u64> {
    rs.iter()
        .map(|&(_, c)| oc_shard_bytes(model, stage, c))
        .collect()
}

/// Build the layer-by-layer OC plan.
pub fn plan_oc(model: &Model, cluster: &Cluster) -> Plan {
    let m = cluster.m();
    let shares = cluster.compute_shares();
    let mut stages = Vec::new();
    // (channel ranges, producing stage) of the previous stage's output
    let mut prev: Option<(Vec<(usize, usize)>, Stage)> = None;

    for &stage in model.stages() {
        let op = &model.ops[stage.op_idx];
        let c_out = op.c_out().expect("stage heads are weighted");
        let counts = proportional_split(c_out, &shares);
        let rs = ranges(&counts);
        let slices: Vec<SliceKind> = rs
            .iter()
            .map(|&(start, count)| {
                if count == 0 {
                    SliceKind::Idle
                } else {
                    SliceKind::Oc { start, count }
                }
            })
            .collect();

        // Every stage needs the full previous activation: AllGather the
        // previous shards (the input image itself is replicated).
        let pre_comm = match &prev {
            None => CommStep::None,
            Some((prev_rs, prev_stage)) => CommStep::AllGather {
                bytes_per_dev: oc_shard_bytes_all(model, *prev_stage, prev_rs),
            },
        };

        stages.push(StagePlan {
            stage,
            pre_comm,
            slices,
            out_layout: Layout::OcShard(rs.clone()),
        });
        prev = Some((rs, stage));
    }

    // Assemble the classifier output on device 0.
    let final_comm = match &prev {
        Some((prev_rs, prev_stage)) => CommStep::Gather {
            root: 0,
            bytes_per_dev: oc_shard_bytes_all(model, *prev_stage, prev_rs),
        },
        None => CommStep::None,
    };

    Plan {
        model_name: model.name.clone(),
        strategy: Strategy::Oc,
        m,
        stages,
        final_comm,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::profiles;
    use crate::model::zoo;

    #[test]
    fn plan_is_valid_for_all_models() {
        let cluster = profiles::paper_default();
        for m in zoo::all_models() {
            let p = plan_oc(&m, &cluster);
            p.validate(&m).unwrap();
        }
    }

    #[test]
    fn every_interior_stage_allgathers() {
        let model = zoo::lenet();
        let p = plan_oc(&model, &profiles::paper_default());
        assert!(matches!(p.stages[0].pre_comm, CommStep::None));
        for s in &p.stages[1..] {
            assert!(
                matches!(s.pre_comm, CommStep::AllGather { .. }),
                "stage {:?} should allgather",
                s.stage
            );
        }
        assert!(matches!(p.final_comm, CommStep::Gather { .. }));
    }

    #[test]
    fn connection_count_is_m_m1_per_interior_stage() {
        let model = zoo::lenet();
        let cluster = profiles::paper_default();
        let p = plan_oc(&model, &cluster);
        let m = cluster.m();
        // 5 stages: 4 interior AllGathers (m(m-1) each) + final gather (m-1)
        assert_eq!(p.total_connections(), 4 * m * (m - 1) + (m - 1));
    }

    #[test]
    fn allgather_bytes_match_activation_size() {
        let model = zoo::lenet();
        let p = plan_oc(&model, &profiles::paper_default());
        // stage 1's pre-AllGather moves exactly stage 0's full output,
        // (m-1) times over.
        let stage0_out = model.stage_out_shape(model.stages()[0]);
        if let CommStep::AllGather { bytes_per_dev } = &p.stages[1].pre_comm {
            let total: u64 = bytes_per_dev.iter().sum();
            assert_eq!(total, stage0_out.bytes());
        } else {
            panic!("expected allgather");
        }
    }

    #[test]
    fn heterogeneous_shares_skew_slices() {
        let model = zoo::vgg11();
        let cluster = profiles::heterogeneous();
        let p = plan_oc(&model, &cluster);
        p.validate(&model).unwrap();
        // fastest device gets the largest channel count on a wide layer
        let wide = &p.stages[4]; // 512-channel conv
        let counts: Vec<usize> = wide.slices.iter().map(|s| s.count()).collect();
        assert!(counts[0] > counts[1] && counts[1] > counts[2], "{counts:?}");
    }

    #[test]
    fn shard_bytes_scale_through_tail() {
        let model = zoo::lenet();
        let stages = model.stages();
        // stage 1 = conv2+pool2+flatten: 16 channels -> 400 features,
        // so 4 channels -> 4 x (5x5) x 4 bytes.
        let b = oc_shard_bytes(&model, stages[1], 4);
        assert_eq!(b, (4 * 25 * 4) as u64);
    }
}
