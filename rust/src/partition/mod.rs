//! Partition planning: the three strategies of §5 (OC, CoEdge, IOP), the
//! plan IR they share, and the supporting integer-allocation / row-range
//! arithmetic.

pub mod coedge;
pub mod iop;
pub mod oc;
pub mod plan;
pub mod rows;
pub mod split;

pub use plan::{CommStep, Layout, Plan, Segment, SliceKind, StagePlan, Strategy};
