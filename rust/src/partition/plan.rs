//! Partition plan IR — the common language between the planners
//! (`oc`/`coedge`/`iop`), the cost model, the discrete-event simulator, and
//! the distributed executor.
//!
//! A `Plan` assigns, per *stage* (weighted op + its passthrough tail, see
//! `model::graph`), a slice of work to every device plus the communication
//! step required to make the stage's inputs available (`pre_comm`). The
//! final assembly of the network output is `final_comm`.
//!
//! Layout is the activation's distribution state between stages; comm steps
//! are layout *transitions*. This is how the paper's central observation is
//! encoded: an OC-partitioned producer followed by an IC-partitioned
//! consumer is the identity transition (`CommStep::None`).

use crate::model::graph::Stage;
use crate::model::Model;
use crate::util::json::Json;

/// Partitioning strategy (the three compared in §5).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Strategy {
    /// Layer-by-layer output-channel partitioning (AlexNet baseline).
    Oc,
    /// CoEdge-style feature-map H partitioning (conv only, FC on root).
    CoEdge,
    /// Interleaved Operator Partitioning with greedy segmentation (ours).
    Iop,
}

impl Strategy {
    pub fn name(&self) -> &'static str {
        match self {
            Strategy::Oc => "OC",
            Strategy::CoEdge => "CoEdge",
            Strategy::Iop => "IOP",
        }
    }

    pub fn parse(s: &str) -> Option<Strategy> {
        match s.to_ascii_lowercase().as_str() {
            "oc" => Some(Strategy::Oc),
            "coedge" => Some(Strategy::CoEdge),
            "iop" => Some(Strategy::Iop),
            _ => None,
        }
    }

    pub fn all() -> [Strategy; 3] {
        [Strategy::Oc, Strategy::CoEdge, Strategy::Iop]
    }
}

/// Distribution state of an activation across the cluster.
#[derive(Debug, Clone, PartialEq)]
pub enum Layout {
    /// Every device holds the full activation.
    Replicated,
    /// Device `j` holds channel block `ranges[j]` (over channels, or over
    /// flattened features after a `Flatten`).
    OcShard(Vec<(usize, usize)>),
    /// Device `j` holds output-row block `ranges[j]`.
    RowShard(Vec<(usize, usize)>),
    /// Every device holds a full-shape *partial sum* (IC-partitioned
    /// producer); values must be reduced before use.
    Partial,
    /// Only device `root` holds the activation.
    RootOnly(usize),
}

/// Work slice of one device for one stage.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SliceKind {
    /// The entire stage (solo execution).
    Full,
    /// Output channels `[start, start+count)`.
    Oc { start: usize, count: usize },
    /// Input channels `[start, start+count)` — produces a partial sum over
    /// all output channels.
    Ic { start: usize, count: usize },
    /// Output rows `[start, start+count)` (of the stage's *final* output,
    /// i.e. after the passthrough tail).
    Rows { start: usize, count: usize },
    /// The entire stage, redundantly, on every device (CoEdge's
    /// unpartitioned FC phase: activations are broadcast + concatenated and
    /// each device evaluates the classifier in full — Fig. 3).
    Replicate,
    /// No work this stage.
    Idle,
}

impl SliceKind {
    /// Fraction of the stage's total work this slice represents.
    pub fn work_fraction(&self, denom: usize) -> f64 {
        match self {
            SliceKind::Full | SliceKind::Replicate => 1.0,
            SliceKind::Idle => 0.0,
            SliceKind::Oc { count, .. }
            | SliceKind::Ic { count, .. }
            | SliceKind::Rows { count, .. } => *count as f64 / denom as f64,
        }
    }

    pub fn count(&self) -> usize {
        match self {
            SliceKind::Oc { count, .. }
            | SliceKind::Ic { count, .. }
            | SliceKind::Rows { count, .. } => *count,
            _ => 0,
        }
    }

    pub fn is_idle(&self) -> bool {
        matches!(self, SliceKind::Idle) || self.count() == 0 && !matches!(self, SliceKind::Full)
    }
}

/// A point-to-point transfer: `(from, to, bytes)`.
pub type Xfer = (usize, usize, u64);

/// Communication step — a layout transition on the shared medium. Every
/// message (unicast transfer) pays the connection-establishment latency
/// `t_est` plus `bytes / b` (paper eq. 8); the shared medium serializes
/// messages (DESIGN.md §2).
#[derive(Debug, Clone, PartialEq)]
pub enum CommStep {
    /// No communication (the IOP intra-pair case, or locally satisfiable
    /// re-layouts such as Replicated → any shard).
    None,
    /// Every device broadcasts its shard to all `m-1` peers
    /// (shard → Replicated). `bytes_per_dev[j]` is device j's shard size.
    AllGather { bytes_per_dev: Vec<u64> },
    /// Partial sums are sent to `root`, reduced, and the result broadcast
    /// back (Partial → Replicated). 2(m-1) messages of `bytes`.
    ReduceBroadcast { root: usize, bytes: u64 },
    /// Partial sums are sent to `root` and reduced there
    /// (Partial → RootOnly). (m-1) messages of `bytes`.
    ReduceTo { root: usize, bytes: u64 },
    /// Shards are gathered on `root` (shard → RootOnly).
    Gather { root: usize, bytes_per_dev: Vec<u64> },
    /// `root` sends the full activation to everyone (RootOnly → Replicated).
    Broadcast { root: usize, bytes: u64 },
    /// Row-neighbour halo exchange (RowShard → RowShard with halos).
    HaloExchange { xfers: Vec<Xfer> },
}

impl CommStep {
    /// All unicast messages implied by this step, as (from, to, bytes).
    pub fn messages(&self, m: usize) -> Vec<Xfer> {
        match self {
            CommStep::None => vec![],
            CommStep::AllGather { bytes_per_dev } => {
                let mut out = Vec::new();
                for (j, &b) in bytes_per_dev.iter().enumerate() {
                    if b == 0 {
                        continue;
                    }
                    for k in 0..m {
                        if k != j {
                            out.push((j, k, b));
                        }
                    }
                }
                out
            }
            CommStep::ReduceBroadcast { root, bytes } => {
                let mut out = Vec::new();
                for j in 0..m {
                    if j != *root {
                        out.push((j, *root, *bytes));
                    }
                }
                for j in 0..m {
                    if j != *root {
                        out.push((*root, j, *bytes));
                    }
                }
                out
            }
            CommStep::ReduceTo { root, bytes } => (0..m)
                .filter(|j| j != root)
                .map(|j| (j, *root, *bytes))
                .collect(),
            CommStep::Gather {
                root,
                bytes_per_dev,
            } => bytes_per_dev
                .iter()
                .enumerate()
                .filter(|(j, &b)| *j != *root && b > 0)
                .map(|(j, &b)| (j, *root, b))
                .collect(),
            CommStep::Broadcast { root, bytes } => (0..m)
                .filter(|j| j != root)
                .map(|j| (*root, j, *bytes))
                .collect(),
            CommStep::HaloExchange { xfers } => xfers.clone(),
        }
    }

    /// Number of connections (t_est-bearing messages) — the quantity the
    /// paper's IOP argument minimizes.
    pub fn connections(&self, m: usize) -> usize {
        self.messages(m).len()
    }

    /// Total bytes moved.
    pub fn total_bytes(&self, m: usize) -> u64 {
        self.messages(m).iter().map(|(_, _, b)| *b).sum()
    }

    pub fn tag(&self) -> &'static str {
        match self {
            CommStep::None => "none",
            CommStep::AllGather { .. } => "all_gather",
            CommStep::ReduceBroadcast { .. } => "reduce_bcast",
            CommStep::ReduceTo { .. } => "reduce_to",
            CommStep::Gather { .. } => "gather",
            CommStep::Broadcast { .. } => "broadcast",
            CommStep::HaloExchange { .. } => "halo",
        }
    }
}

/// A segmentation entry (paper §4, eq. 9): either a single stage or an
/// IOP-paired run of two adjacent stages. Indices refer to
/// `Model::stages()`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Segment {
    /// Stage `i` alone (partitioned CoEdge-style).
    Single(usize),
    /// Stages `i` (OC) and `i+1` (IC) interleaved — no comm inside.
    Pair(usize),
}

impl Segment {
    pub fn first(&self) -> usize {
        match self {
            Segment::Single(i) | Segment::Pair(i) => *i,
        }
    }

    pub fn len(&self) -> usize {
        match self {
            Segment::Single(_) => 1,
            Segment::Pair(_) => 2,
        }
    }
}

/// Check a segmentation tiles `n_stages` exactly, in order.
pub fn validate_segments(segments: &[Segment], n_stages: usize) -> Result<(), String> {
    let mut pos = 0;
    for s in segments {
        if s.first() != pos {
            return Err(format!("segment at {} expected at {}", s.first(), pos));
        }
        pos += s.len();
    }
    if pos != n_stages {
        return Err(format!("segments cover {pos} of {n_stages} stages"));
    }
    Ok(())
}

/// Per-stage plan entry.
#[derive(Debug, Clone, PartialEq)]
pub struct StagePlan {
    pub stage: Stage,
    /// Communication required *before* this stage runs.
    pub pre_comm: CommStep,
    /// Per-device work slice.
    pub slices: Vec<SliceKind>,
    /// Activation layout after this stage (before the next pre_comm).
    pub out_layout: Layout,
}

/// A complete partition plan for one model on one cluster.
#[derive(Debug, Clone, PartialEq)]
pub struct Plan {
    pub model_name: String,
    pub strategy: Strategy,
    pub m: usize,
    pub stages: Vec<StagePlan>,
    /// Communication to assemble the network output on device 0.
    pub final_comm: CommStep,
}

impl Plan {
    /// Total connection count across the plan (paper's reduced-connections
    /// claim is checked against this in the integration tests).
    pub fn total_connections(&self) -> usize {
        self.stages
            .iter()
            .map(|s| s.pre_comm.connections(self.m))
            .sum::<usize>()
            + self.final_comm.connections(self.m)
    }

    /// Total bytes communicated.
    pub fn total_comm_bytes(&self) -> u64 {
        self.stages
            .iter()
            .map(|s| s.pre_comm.total_bytes(self.m))
            .sum::<u64>()
            + self.final_comm.total_bytes(self.m)
    }

    /// Validate the paper's structural constraints (eqs. 2–5) against the
    /// model: every stage has exactly one partition dimension, and slice
    /// ranges tile their dimension exactly.
    pub fn validate(&self, model: &Model) -> Result<(), String> {
        if self.stages.len() != model.stages().len() {
            return Err(format!(
                "plan has {} stages, model has {}",
                self.stages.len(),
                model.stages().len()
            ));
        }
        for (si, sp) in self.stages.iter().enumerate() {
            if sp.slices.len() != self.m {
                return Err(format!("stage {si}: {} slices for m={}", sp.slices.len(), self.m));
            }
            let op = &model.ops[sp.stage.op_idx];
            // Rows are defined over the spatial output (before flatten).
            let out_shape = model.stage_spatial_out_shape(sp.stage);
            // eq. 2: one dimension per stage — all non-idle slices must be
            // the same variant.
            let mut kinds: Vec<&'static str> = sp
                .slices
                .iter()
                .filter(|s| !matches!(s, SliceKind::Idle))
                .map(|s| match s {
                    SliceKind::Full => "full",
                    SliceKind::Replicate => "replicate",
                    SliceKind::Oc { .. } => "oc",
                    SliceKind::Ic { .. } => "ic",
                    SliceKind::Rows { .. } => "rows",
                    SliceKind::Idle => unreachable!(),
                })
                .collect();
            kinds.dedup();
            if kinds.len() > 1 {
                return Err(format!("stage {si}: mixed slice kinds {kinds:?} (violates eq. 2)"));
            }
            // eqs. 3–5: exact tiling of the partitioned dimension.
            match kinds.first() {
                Some(&"oc") => {
                    let dim = op.c_out().ok_or(format!("stage {si}: OC slice on unweighted op"))?;
                    check_tiling(si, "OC", dim, sp.slices.iter())?;
                }
                Some(&"ic") => {
                    let dim = op.c_in().ok_or(format!("stage {si}: IC slice on unweighted op"))?;
                    check_tiling(si, "IC", dim, sp.slices.iter())?;
                }
                Some(&"rows") => {
                    check_tiling(si, "H", out_shape.h, sp.slices.iter())?;
                }
                Some(&"full") => {
                    let n_full = sp
                        .slices
                        .iter()
                        .filter(|s| matches!(s, SliceKind::Full))
                        .count();
                    if n_full != 1 {
                        return Err(format!("stage {si}: {n_full} Full slices (must be exactly 1)"));
                    }
                }
                Some(&"replicate") => {
                    // every device must replicate (no partial redundancy)
                    if !sp.slices.iter().all(|s| matches!(s, SliceKind::Replicate)) {
                        return Err(format!("stage {si}: mixed Replicate/other slices"));
                    }
                }
                _ => return Err(format!("stage {si}: all devices idle")),
            }
        }
        Ok(())
    }

    /// Human/machine-readable plan description.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("model", Json::str(self.model_name.clone())),
            ("strategy", Json::str(self.strategy.name())),
            ("m", Json::num(self.m as f64)),
            ("connections", Json::num(self.total_connections() as f64)),
            ("comm_bytes", Json::num(self.total_comm_bytes() as f64)),
            (
                "stages",
                Json::arr(
                    self.stages
                        .iter()
                        .map(|s| {
                            Json::obj(vec![
                                ("op", Json::num(s.stage.op_idx as f64)),
                                ("pre_comm", Json::str(s.pre_comm.tag())),
                                (
                                    "slices",
                                    Json::arr(
                                        s.slices
                                            .iter()
                                            .map(|sl| {
                                                Json::str(match sl {
                                                    SliceKind::Full => "full".to_string(),
                                                    SliceKind::Replicate => "replicate".to_string(),
                                                    SliceKind::Idle => "idle".to_string(),
                                                    SliceKind::Oc { start, count } => {
                                                        format!("oc[{start}+{count}]")
                                                    }
                                                    SliceKind::Ic { start, count } => {
                                                        format!("ic[{start}+{count}]")
                                                    }
                                                    SliceKind::Rows { start, count } => {
                                                        format!("rows[{start}+{count}]")
                                                    }
                                                })
                                            })
                                            .collect(),
                                    ),
                                ),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }
}

fn check_tiling<'a>(
    si: usize,
    dim_name: &str,
    dim: usize,
    slices: impl Iterator<Item = &'a SliceKind>,
) -> Result<(), String> {
    let mut ranges: Vec<(usize, usize)> = slices
        .filter_map(|s| match s {
            SliceKind::Oc { start, count }
            | SliceKind::Ic { start, count }
            | SliceKind::Rows { start, count } => Some((*start, *count)),
            _ => None,
        })
        .filter(|(_, c)| *c > 0)
        .collect();
    ranges.sort();
    let mut pos = 0;
    for (s, c) in &ranges {
        if *s != pos {
            return Err(format!(
                "stage {si}: {dim_name} ranges not contiguous at {pos} (got start {s})"
            ));
        }
        pos += c;
    }
    if pos != dim {
        return Err(format!(
            "stage {si}: {dim_name} ranges cover {pos} of {dim} (violates eqs. 3-5)"
        ));
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn allgather_messages() {
        let c = CommStep::AllGather {
            bytes_per_dev: vec![10, 20, 0],
        };
        let msgs = c.messages(3);
        // device 2 has nothing to send; devices 0 and 1 send to 2 peers each
        assert_eq!(msgs.len(), 4);
        assert_eq!(c.total_bytes(3), 2 * 10 + 2 * 20);
        assert_eq!(c.connections(3), 4);
    }

    #[test]
    fn reduce_broadcast_connection_count() {
        // The IOP claim: 2(m-1) connections vs AllGather's m(m-1).
        let m = 3;
        let rb = CommStep::ReduceBroadcast { root: 0, bytes: 100 };
        let ag = CommStep::AllGather {
            bytes_per_dev: vec![100; m],
        };
        assert_eq!(rb.connections(m), 2 * (m - 1));
        assert_eq!(ag.connections(m), m * (m - 1));
    }

    #[test]
    fn gather_excludes_root() {
        let g = CommStep::Gather {
            root: 1,
            bytes_per_dev: vec![5, 7, 9],
        };
        let msgs = g.messages(3);
        assert_eq!(msgs, vec![(0, 1, 5), (2, 1, 9)]);
    }

    #[test]
    fn broadcast_and_reduce_to() {
        assert_eq!(
            CommStep::Broadcast { root: 0, bytes: 3 }.messages(3),
            vec![(0, 1, 3), (0, 2, 3)]
        );
        assert_eq!(
            CommStep::ReduceTo { root: 2, bytes: 4 }.messages(3),
            vec![(0, 2, 4), (1, 2, 4)]
        );
    }

    #[test]
    fn strategy_parse() {
        assert_eq!(Strategy::parse("IOP"), Some(Strategy::Iop));
        assert_eq!(Strategy::parse("coedge"), Some(Strategy::CoEdge));
        assert_eq!(Strategy::parse("oc"), Some(Strategy::Oc));
        assert_eq!(Strategy::parse("xyz"), None);
    }
}
