//! `iop` binary — the L3 coordinator CLI.
//!
//! See `iop help` (or `cli::run`) for the command surface; DESIGN.md maps
//! each command to the paper experiment it regenerates.

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = iop::cli::run(argv) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}
