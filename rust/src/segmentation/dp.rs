//! Exact segmentation by dynamic programming over `(stage, boundary tag)`.
//!
//! The exact per-segment costs in `costs` depend only on the entry
//! boundary state, and each segment's exit state is a function of the
//! segment — so the DP state `(i, tag)` gives optimal substructure that
//! prices *exactly* what `plan_iop_with_segments` builds (verified against
//! `cost::evaluate` in the module tests).
//!
//! The paper ships the greedy Algorithm 1; this solver is our ablation —
//! `benches/ablation_segmentation.rs` measures how much latency greedy
//! leaves on the table.

use super::costs::{final_cost, pair_cost_exact, single_cost_exact, BoundaryTag};
use crate::device::Cluster;
use crate::model::Model;
use crate::partition::iop::pairable;
use crate::partition::Segment;
use std::collections::HashMap;

/// Exact minimum-latency segmentation.
pub fn dp(model: &Model, cluster: &Cluster) -> Vec<Segment> {
    let stages = model.stages();
    let n = stages.len();
    // memo[(i, tag)] = (best suffix cost, segment chosen at i)
    let mut memo: HashMap<(usize, BoundaryTag), (f64, Option<Segment>)> = HashMap::new();

    fn solve(
        i: usize,
        tag: BoundaryTag,
        n: usize,
        model: &Model,
        cluster: &Cluster,
        memo: &mut HashMap<(usize, BoundaryTag), (f64, Option<Segment>)>,
    ) -> f64 {
        if i == n {
            return final_cost(model, cluster, tag);
        }
        if let Some((c, _)) = memo.get(&(i, tag)) {
            return *c;
        }
        let (sc, s_tag) = single_cost_exact(model, cluster, i, tag);
        let mut best = sc + solve(i + 1, s_tag, n, model, cluster, memo);
        let mut choice = Segment::Single(i);
        let stages = model.stages();
        if i + 1 < n && pairable(model, stages[i], stages[i + 1]) {
            let (pc, p_tag) = pair_cost_exact(model, cluster, i, tag);
            let total = pc + solve(i + 2, p_tag, n, model, cluster, memo);
            if total < best {
                best = total;
                choice = Segment::Pair(i);
            }
        }
        memo.insert((i, tag), (best, Some(choice)));
        best
    }

    let _ = solve(0, BoundaryTag::Rep, n, model, cluster, &mut memo);

    // Reconstruct the path.
    let mut segments = Vec::new();
    let mut i = 0;
    let mut tag = BoundaryTag::Rep;
    while i < n {
        let (_, choice) = memo[&(i, tag)];
        let seg = choice.expect("dp covered every state");
        match seg {
            Segment::Single(_) => {
                let (_, t) = single_cost_exact(model, cluster, i, tag);
                tag = t;
                i += 1;
            }
            Segment::Pair(_) => {
                let (_, t) = pair_cost_exact(model, cluster, i, tag);
                tag = t;
                i += 2;
            }
        }
        segments.push(seg);
    }
    segments
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::profiles;
    use crate::model::zoo;
    use crate::partition::plan::validate_segments;
    use crate::segmentation::segmentation_cost;

    #[test]
    fn valid_for_all_models() {
        let cluster = profiles::paper_default();
        for m in zoo::all_models() {
            validate_segments(&dp(&m, &cluster), m.stages().len()).unwrap();
        }
    }

    #[test]
    fn never_beaten_by_trivial_patterns() {
        let cluster = profiles::paper_default();
        for m in zoo::fig4_models() {
            let d = segmentation_cost(&m, &cluster, &dp(&m, &cluster));
            let n = m.stages().len();
            let all_singles: Vec<Segment> = (0..n).map(Segment::Single).collect();
            assert!(
                d <= segmentation_cost(&m, &cluster, &all_singles) + 1e-9,
                "{}",
                m.name
            );
        }
    }
}
