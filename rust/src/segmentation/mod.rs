//! Model segmentation (paper §4): choose `Γ = [γ_1 … γ_k]` — which
//! adjacent stage pairs get IOP treatment and which stages stay single
//! (CoEdge-partitioned) — to minimize the end-to-end inference delay.
//!
//! Three solvers:
//!  * [`greedy`] — the paper's Algorithm 1: scan left to right, pair
//!    `(o_i, o_{i+1})` iff the pair's IOP time beats its CoEdge time.
//!  * [`dp`] — exact dynamic program over segment boundaries (the segment
//!    costs are boundary-normalized, so optimal substructure holds).
//!  * [`exhaustive`] — brute-force enumeration of all single/pair tilings;
//!    exponential, used as the oracle in tests and the ablation bench.

pub mod costs;
pub mod dp;
pub mod exhaustive;
pub mod greedy;

pub use dp::dp;
pub use exhaustive::exhaustive;
pub use greedy::greedy;

use crate::device::Cluster;
use crate::model::Model;
use crate::partition::iop::plan_iop_with_segments;
use crate::partition::{Plan, Segment};

/// The paper's IOP strategy end-to-end: greedy segmentation (Algorithm 1)
/// followed by IOP plan construction.
pub fn plan_iop(model: &Model, cluster: &Cluster) -> Plan {
    let segments = greedy(model, cluster);
    plan_iop_with_segments(model, cluster, &segments)
}

/// IOP with the exact-DP segmentation (ablation: how much does greedy
/// leave on the table?).
pub fn plan_iop_dp(model: &Model, cluster: &Cluster) -> Plan {
    let segments = dp(model, cluster);
    plan_iop_with_segments(model, cluster, &segments)
}

/// True end-to-end cost of a segmentation: build the actual plan and
/// evaluate it under the analytic model (P1).
pub fn segmentation_cost(model: &Model, cluster: &Cluster, segments: &[Segment]) -> f64 {
    let plan = plan_iop_with_segments(model, cluster, segments);
    crate::cost::evaluate(model, cluster, &plan).total_secs
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::profiles;
    use crate::model::zoo;
    use crate::partition::plan::validate_segments;

    #[test]
    fn greedy_produces_valid_segmentation() {
        let cluster = profiles::paper_default();
        for m in zoo::all_models() {
            let segs = greedy(&m, &cluster);
            validate_segments(&segs, m.stages().len()).unwrap();
        }
    }

    #[test]
    fn plans_from_all_solvers_validate() {
        let cluster = profiles::paper_default();
        for m in zoo::fig4_models() {
            plan_iop(&m, &cluster).validate(&m).unwrap();
            plan_iop_dp(&m, &cluster).validate(&m).unwrap();
        }
    }

    #[test]
    fn dp_never_worse_than_greedy() {
        let cluster = profiles::paper_default();
        for m in zoo::all_models() {
            let g = segmentation_cost(&m, &cluster, &greedy(&m, &cluster));
            let d = segmentation_cost(&m, &cluster, &dp(&m, &cluster));
            assert!(d <= g + 1e-12, "{}: dp={d} greedy={g}", m.name);
        }
    }

    #[test]
    fn dp_matches_exhaustive_oracle() {
        let cluster = profiles::paper_default();
        for m in [zoo::lenet(), zoo::alexnet(), zoo::vgg11()] {
            let d = segmentation_cost(&m, &cluster, &dp(&m, &cluster));
            let e = segmentation_cost(&m, &cluster, &exhaustive(&m, &cluster));
            assert!((d - e).abs() < 1e-9, "{}: dp={d} exhaustive={e}", m.name);
        }
    }

    #[test]
    fn dp_cost_model_matches_true_plan_cost() {
        // The DP's incremental accounting must agree with pricing the
        // plan it reconstructs.
        use crate::segmentation::costs::{
            final_cost, pair_cost_exact, single_cost_exact, BoundaryTag,
        };
        let cluster = profiles::paper_default();
        for m in zoo::fig4_models() {
            let segs = dp(&m, &cluster);
            let mut tag = BoundaryTag::Rep;
            let mut acc = 0.0;
            for s in &segs {
                match *s {
                    crate::partition::Segment::Single(i) => {
                        let (c, t) = single_cost_exact(&m, &cluster, i, tag);
                        acc += c;
                        tag = t;
                    }
                    crate::partition::Segment::Pair(i) => {
                        let (c, t) = pair_cost_exact(&m, &cluster, i, tag);
                        acc += c;
                        tag = t;
                    }
                }
            }
            acc += final_cost(&m, &cluster, tag);
            let truth = segmentation_cost(&m, &cluster, &segs);
            assert!(
                (acc - truth).abs() / truth < 1e-9,
                "{}: dp-accounting={acc} plan={truth}",
                m.name
            );
        }
    }

    #[test]
    fn fc_stages_get_paired() {
        // FC singles serialize on the root under CoEdge, so Algorithm 1
        // should IOP-pair the classifier stages of every model.
        let cluster = profiles::paper_default();
        let m = zoo::alexnet();
        let segs = greedy(&m, &cluster);
        let fc_start = m
            .stages()
            .iter()
            .position(|s| m.ops[s.op_idx].kind_tag() == "fc")
            .unwrap();
        let has_fc_pair = segs
            .iter()
            .any(|s| {
                matches!(s, crate::partition::Segment::Pair(i) if *i >= fc_start.saturating_sub(1))
            });
        assert!(has_fc_pair, "{segs:?}");
    }
}
