//! Brute-force segmentation oracle.
//!
//! Enumerates every tiling of the stage sequence into singles and
//! (pairable) pairs — Fibonacci-many, fine for n ≤ ~25 — and prices each
//! candidate by building the *actual plan* and evaluating it under the
//! analytic model. Certifies `dp` (and measures how near-optimal the
//! paper's greedy is) in tests and the ablation bench.

use crate::cost;
use crate::device::Cluster;
use crate::model::Model;
use crate::partition::iop::{pairable, plan_iop_with_segments};
use crate::partition::Segment;

/// Exhaustively search all segmentations; returns the cheapest by true
/// plan cost.
pub fn exhaustive(model: &Model, cluster: &Cluster) -> Vec<Segment> {
    let stages = model.stages();
    let n = stages.len();
    assert!(n <= 25, "exhaustive search is exponential; n={n} too large");

    let mut best_cost = f64::INFINITY;
    let mut best: Vec<Segment> = Vec::new();
    let mut current: Vec<Segment> = Vec::new();

    #[allow(clippy::too_many_arguments)]
    fn recurse(
        i: usize,
        n: usize,
        model: &Model,
        cluster: &Cluster,
        stages: &[crate::model::Stage],
        current: &mut Vec<Segment>,
        best_cost: &mut f64,
        best: &mut Vec<Segment>,
    ) {
        if i == n {
            let plan = plan_iop_with_segments(model, cluster, current);
            let c = cost::evaluate(model, cluster, &plan).total_secs;
            if c < *best_cost {
                *best_cost = c;
                *best = current.clone();
            }
            return;
        }
        current.push(Segment::Single(i));
        recurse(i + 1, n, model, cluster, stages, current, best_cost, best);
        current.pop();
        if i + 1 < n && pairable(model, stages[i], stages[i + 1]) {
            current.push(Segment::Pair(i));
            recurse(i + 2, n, model, cluster, stages, current, best_cost, best);
            current.pop();
        }
    }

    recurse(
        0,
        n,
        model,
        cluster,
        &stages,
        &mut current,
        &mut best_cost,
        &mut best,
    );
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::profiles;
    use crate::model::zoo;
    use crate::partition::plan::validate_segments;

    #[test]
    fn valid_and_complete() {
        let m = zoo::lenet();
        let segs = exhaustive(&m, &profiles::paper_default());
        validate_segments(&segs, m.stages().len()).unwrap();
    }

    #[test]
    fn beats_or_ties_every_fixed_pattern() {
        use crate::segmentation::segmentation_cost;
        let m = zoo::alexnet();
        let c = profiles::paper_default();
        let e = segmentation_cost(&m, &c, &exhaustive(&m, &c));
        let n = m.stages().len();
        let all_singles: Vec<Segment> = (0..n).map(Segment::Single).collect();
        assert!(e <= segmentation_cost(&m, &c, &all_singles) + 1e-12);
    }
}
