//! Segment costs for the segmentation solvers.
//!
//! The cost of a segment depends on what the *previous* segment left
//! behind — a row-sharded activation, full-shape partial sums, or a
//! replicated tensor. [`BoundaryTag`] captures that state and the entry
//! costs here mirror the transitions `partition::iop` emits one-for-one,
//! so the DP over `(stage, tag)` prices exactly what the planner builds
//! (asserted by `segmentation::tests::dp_matches_true_plan_cost`).
//!
//! The paper's greedy Algorithm 1 uses the *pairwise* comparators at the
//! bottom (`pair_iop_cost_vs` / `pair_coedge_cost_vs`): both alternatives
//! are charged to a common "replicated at exit" convention so the local
//! comparison is fair.

use crate::cost::comm::step_secs;
use crate::cost::compute::stage_compute_wall;
use crate::device::Cluster;
use crate::model::{Model, OpKind, Stage};
use crate::partition::coedge::{MIN_ROWS, ROOT};
use crate::partition::plan::{CommStep, SliceKind};
use crate::partition::rows::halo_xfers;
use crate::partition::split::{proportional_split, proportional_split_min, ranges};

/// Activation state at a segment boundary (after stage `i-1`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum BoundaryTag {
    /// Every device has the full activation (model input, after an FC
    /// replicate stage, or after a pair's reduce+broadcast).
    Rep,
    /// Row-sharded (after a CoEdge-partitioned conv single).
    Row,
    /// Full-shape partial sums (after an IOP pair, pre-reduction).
    Partial,
}

// ---------- shared split helpers ----------

pub(crate) fn oc_slices(model: &Model, stage: Stage, cluster: &Cluster) -> Vec<SliceKind> {
    let c_out = model.ops[stage.op_idx].c_out().unwrap();
    ranges(&proportional_split(c_out, &cluster.compute_shares()))
        .into_iter()
        .map(|(start, count)| {
            if count == 0 {
                SliceKind::Idle
            } else {
                SliceKind::Oc { start, count }
            }
        })
        .collect()
}

/// IC slices for pair stage B, aligned to stage A's OC blocks exactly as
/// `plan_iop_with_segments` aligns them (scaled through a flatten).
pub(crate) fn ic_slices_aligned(
    model: &Model,
    stage_a: Stage,
    stage_b: Stage,
    cluster: &Cluster,
) -> Vec<SliceKind> {
    let c_out_a = model.ops[stage_a.op_idx].c_out().unwrap();
    let scale = match model.ops[stage_b.op_idx].kind {
        OpKind::Dense { c_in, .. } => c_in / c_out_a,
        _ => 1,
    };
    ranges(&proportional_split(c_out_a, &cluster.compute_shares()))
        .into_iter()
        .map(|(start, count)| {
            if count == 0 {
                SliceKind::Idle
            } else {
                SliceKind::Ic {
                    start: start * scale,
                    count: count * scale,
                }
            }
        })
        .collect()
}

pub(crate) fn row_ranges(model: &Model, stage: Stage, cluster: &Cluster) -> Vec<(usize, usize)> {
    let h = model.stage_spatial_out_shape(stage).h;
    ranges(&proportional_split_min(
        h,
        &cluster.compute_shares(),
        MIN_ROWS.min(h),
    ))
}

fn row_slices(rs: &[(usize, usize)]) -> Vec<SliceKind> {
    rs.iter()
        .map(|&(start, count)| {
            if count == 0 {
                SliceKind::Idle
            } else {
                SliceKind::Rows { start, count }
            }
        })
        .collect()
}

/// AllGather step for the row-sharded output of stage `i-1`.
fn row_allgather(model: &Model, cluster: &Cluster, prev_stage: Stage) -> CommStep {
    let out = model.stage_spatial_out_shape(prev_stage);
    let row_bytes = (out.elems() / out.h * 4) as u64;
    let rs = row_ranges(model, prev_stage, cluster);
    CommStep::AllGather {
        bytes_per_dev: rs.iter().map(|&(_, c)| c as u64 * row_bytes).collect(),
    }
}

/// ReduceBroadcast step for the raw partial output of stage `i-1`.
fn partial_reduce(model: &Model, prev_stage: Stage) -> CommStep {
    CommStep::ReduceBroadcast {
        root: ROOT,
        bytes: model.out_shape(prev_stage.op_idx).bytes(),
    }
}

// ---------- exact per-segment costs (used by the DP) ----------

/// Cost to make stage `i`'s input replicated, given the boundary tag.
pub fn to_rep_cost(model: &Model, cluster: &Cluster, i: usize, tag: BoundaryTag) -> f64 {
    if i == 0 {
        return 0.0; // model input is replicated
    }
    let prev = model.stages()[i - 1];
    match tag {
        BoundaryTag::Rep => 0.0,
        BoundaryTag::Row => step_secs(cluster, &row_allgather(model, cluster, prev)),
        BoundaryTag::Partial => step_secs(cluster, &partial_reduce(model, prev)),
    }
}

/// Entry cost of a CoEdge conv single at stage `i` (halo when coming from
/// a row-sharded neighbour, otherwise the replication cost).
pub fn conv_entry_cost(model: &Model, cluster: &Cluster, i: usize, tag: BoundaryTag) -> f64 {
    if i == 0 {
        return 0.0;
    }
    let stages = model.stages();
    match tag {
        BoundaryTag::Row => {
            let out_rs = row_ranges(model, stages[i], cluster);
            let owned = row_ranges(model, stages[i - 1], cluster);
            let x = halo_xfers(model, stages[i], &out_rs, &owned);
            if x.is_empty() {
                0.0
            } else {
                step_secs(cluster, &CommStep::HaloExchange { xfers: x })
            }
        }
        _ => to_rep_cost(model, cluster, i, tag),
    }
}

/// Exact cost of segment `Single(i)` given the entry tag; returns
/// `(cost, exit_tag)`. Mirrors `plan_iop_with_segments`.
pub fn single_cost_exact(
    model: &Model,
    cluster: &Cluster,
    i: usize,
    tag: BoundaryTag,
) -> (f64, BoundaryTag) {
    let stage = model.stages()[i];
    match model.ops[stage.op_idx].kind {
        OpKind::Conv2d { .. } => {
            let entry = conv_entry_cost(model, cluster, i, tag);
            let rs = row_ranges(model, stage, cluster);
            let compute = stage_compute_wall(model, cluster, stage, &row_slices(&rs));
            (entry + compute, BoundaryTag::Row)
        }
        OpKind::Dense { .. } => {
            let entry = to_rep_cost(model, cluster, i, tag);
            let slices = vec![SliceKind::Replicate; cluster.m()];
            let compute = stage_compute_wall(model, cluster, stage, &slices);
            (entry + compute, BoundaryTag::Rep)
        }
        _ => unreachable!("stage heads are weighted"),
    }
}

/// Exact cost of segment `Pair(i)` given the entry tag; returns
/// `(cost, exit_tag = Partial)`. The pair's reduce is *not* charged here —
/// it is the next segment's (or the final assembly's) entry cost, exactly
/// as the planner defers it.
pub fn pair_cost_exact(
    model: &Model,
    cluster: &Cluster,
    i: usize,
    tag: BoundaryTag,
) -> (f64, BoundaryTag) {
    let stages = model.stages();
    let (sa, sb) = (stages[i], stages[i + 1]);
    let entry = to_rep_cost(model, cluster, i, tag);
    let ca = stage_compute_wall(model, cluster, sa, &oc_slices(model, sa, cluster));
    let cb = stage_compute_wall(
        model,
        cluster,
        sb,
        &ic_slices_aligned(model, sa, sb, cluster),
    );
    (entry + ca + cb, BoundaryTag::Partial)
}

/// Final output-assembly cost given the tag after the last stage.
pub fn final_cost(model: &Model, cluster: &Cluster, tag: BoundaryTag) -> f64 {
    let last = *model.stages().last().unwrap();
    match tag {
        BoundaryTag::Rep => 0.0,
        BoundaryTag::Row => {
            let out = model.stage_spatial_out_shape(last);
            let row_bytes = (out.elems() / out.h * 4) as u64;
            let rs = row_ranges(model, last, cluster);
            step_secs(
                cluster,
                &CommStep::Gather {
                    root: ROOT,
                    bytes_per_dev: rs.iter().map(|&(_, c)| c as u64 * row_bytes).collect(),
                },
            )
        }
        BoundaryTag::Partial => step_secs(
            cluster,
            &CommStep::ReduceTo {
                root: ROOT,
                bytes: model.out_shape(last.op_idx).bytes(),
            },
        ),
    }
}

// ---------- Algorithm-1 pairwise comparators (greedy) ----------

/// `T_iop` for the pair `(i, i+1)` under the common exit-replicated
/// convention: entry (given tag) + both computes + the pair's reduce.
pub fn pair_iop_cost_vs(model: &Model, cluster: &Cluster, i: usize, tag: BoundaryTag) -> f64 {
    let (body, _) = pair_cost_exact(model, cluster, i, tag);
    let sb = model.stages()[i + 1];
    body + step_secs(cluster, &partial_reduce(model, sb))
}

/// `T_co` for the same two stages as CoEdge singles, charged to the same
/// exit-replicated convention (a trailing conv pays its AllGather; a
/// trailing FC replicate is already replicated).
pub fn pair_coedge_cost_vs(model: &Model, cluster: &Cluster, i: usize, tag: BoundaryTag) -> f64 {
    let (c1, tag1) = single_cost_exact(model, cluster, i, tag);
    let (c2, tag2) = single_cost_exact(model, cluster, i + 1, tag1);
    let exit = match tag2 {
        BoundaryTag::Rep => 0.0,
        BoundaryTag::Row => {
            let sb = model.stages()[i + 1];
            step_secs(cluster, &row_allgather(model, cluster, sb))
        }
        BoundaryTag::Partial => unreachable!("singles never exit Partial"),
    };
    c1 + c2 + exit
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::profiles;
    use crate::model::zoo;

    #[test]
    fn fc_pair_iop_beats_replicated_coedge() {
        // Two FC stages: CoEdge replicates them (serial time); IOP
        // partitions both with one reduce. IOP must win on AlexNet's
        // classifier, from either boundary state.
        let m = zoo::alexnet();
        let cluster = profiles::paper_default();
        let stages = m.stages();
        let fc1 = stages
            .iter()
            .position(|s| m.ops[s.op_idx].name == "fc6")
            .unwrap();
        for tag in [BoundaryTag::Rep, BoundaryTag::Row] {
            let iop = pair_iop_cost_vs(&m, &cluster, fc1, tag);
            let co = pair_coedge_cost_vs(&m, &cluster, fc1, tag);
            assert!(iop < co, "{tag:?}: iop={iop} co={co}");
        }
    }

    #[test]
    fn wide_early_conv_pair_prefers_coedge() {
        // VGG's first conv pair has a huge activation: reducing a full
        // 64x224x224 partial costs far more than halo exchange.
        let m = zoo::vgg13();
        let cluster = profiles::paper_default();
        let iop = pair_iop_cost_vs(&m, &cluster, 0, BoundaryTag::Rep);
        let co = pair_coedge_cost_vs(&m, &cluster, 0, BoundaryTag::Rep);
        assert!(co < iop, "co={co} iop={iop}");
    }

    #[test]
    fn alexnet_mid_convs_prefer_coedge_from_row_state() {
        // The regression that motivated tag-aware costs: pairing AlexNet's
        // conv2/conv3 from a row-sharded boundary requires an expensive
        // AllGather + reduce; CoEdge halo must win.
        let m = zoo::alexnet();
        let cluster = profiles::paper_default();
        let iop = pair_iop_cost_vs(&m, &cluster, 1, BoundaryTag::Row);
        let co = pair_coedge_cost_vs(&m, &cluster, 1, BoundaryTag::Row);
        assert!(co < iop, "co={co} iop={iop}");
    }

    #[test]
    fn costs_positive_everywhere() {
        let cluster = profiles::paper_default();
        for m in zoo::fig4_models() {
            let n = m.stages().len();
            for i in 0..n {
                for tag in [BoundaryTag::Rep, BoundaryTag::Row, BoundaryTag::Partial] {
                    let (c, _) = single_cost_exact(&m, &cluster, i, tag);
                    assert!(c > 0.0);
                    if i + 1 < n
                        && crate::partition::iop::pairable(&m, m.stages()[i], m.stages()[i + 1])
                    {
                        assert!(pair_iop_cost_vs(&m, &cluster, i, tag) > 0.0);
                    }
                }
            }
        }
    }

    #[test]
    fn higher_t_est_widens_iop_advantage_over_oc() {
        // Fig. 6's mechanism: per layer pair, OC pays 2 AllGathers
        // (2·m(m-1) connections) where IOP pays one reduce+broadcast
        // (2(m-1)); the gap grows linearly in t_est.
        let m = 3usize;
        let a = 120_000u64;
        let adv = |t_est: f64| {
            let c = profiles::paper_with_t_est(t_est);
            let ag = CommStep::AllGather {
                bytes_per_dev: vec![a / m as u64; m],
            };
            let rb = CommStep::ReduceBroadcast { root: 0, bytes: a };
            2.0 * step_secs(&c, &ag) - step_secs(&c, &rb)
        };
        assert!(adv(0.008) > adv(0.004));
        assert!(adv(0.004) > adv(0.001));
    }

    #[test]
    fn fc_pair_advantage_positive_across_sweep() {
        // IOP must stay ahead of CoEdge's replicated FC phase over the
        // whole Fig. 6 t_est range for the VGG classifier.
        let m = zoo::vgg11();
        let stages = m.stages();
        let fc1 = stages
            .iter()
            .position(|s| m.ops[s.op_idx].kind_tag() == "fc")
            .unwrap();
        for t in [0.001, 0.004, 0.008] {
            let c = profiles::paper_with_t_est(t);
            let adv = pair_coedge_cost_vs(&m, &c, fc1, BoundaryTag::Row)
                - pair_iop_cost_vs(&m, &c, fc1, BoundaryTag::Row);
            assert!(adv > 0.0, "t_est={t}: adv={adv}");
        }
    }
}
