//! Algorithm 1 — the paper's greedy model segmentation and pairing.
//!
//! Scan the weighted stages left to right, tracking the boundary state the
//! plan would be in. For `o_i` and its successor `o_{i+1}`, compare the
//! pair's inference time under IOP (`T_iop`) with the CoEdge treatment of
//! the same two stages (`T_co`), both charged to a common exit-replicated
//! convention; pair them iff `T_iop ≤ T_co`, otherwise emit `o_i` as a
//! single segment and advance by one.

//! The memory constraint (paper eq. 1) is enforced during the scan: if
//! emitting `o_i` as an unpartitioned single would overflow a device's
//! memory capacity (CoEdge's replicated FC stages are the usual culprit),
//! the pair is taken even when its latency estimate loses — exactly the
//! feasibility-first behaviour P1 demands.

use super::costs::{
    ic_slices_aligned, oc_slices, pair_coedge_cost_vs, pair_iop_cost_vs, row_ranges,
    single_cost_exact, BoundaryTag,
};
use crate::cost::memory::{slice_activation_bytes, slice_weight_bytes};
use crate::device::Cluster;
use crate::model::{Model, OpKind, Stage};
use crate::partition::iop::pairable;
use crate::partition::plan::SliceKind;
use crate::partition::Segment;

/// Per-device running eq.-(1) accumulator.
struct MemTracker {
    weights: Vec<u64>,
    peak_act: Vec<u64>,
    caps: Vec<u64>,
}

impl MemTracker {
    fn new(cluster: &Cluster) -> Self {
        Self {
            weights: vec![0; cluster.m()],
            peak_act: vec![0; cluster.m()],
            caps: cluster.devices.iter().map(|d| d.mem_bytes).collect(),
        }
    }

    /// Would adding these per-stage slices keep every device within its
    /// capacity?
    fn feasible_with(&self, model: &Model, stages_slices: &[(Stage, Vec<SliceKind>)]) -> bool {
        for j in 0..self.caps.len() {
            let mut w = self.weights[j];
            let mut a = self.peak_act[j];
            for (stage, slices) in stages_slices {
                w += slice_weight_bytes(model, *stage, &slices[j]);
                a = a.max(slice_activation_bytes(model, *stage, &slices[j]));
            }
            if w + a > self.caps[j] {
                return false;
            }
        }
        true
    }

    fn commit(&mut self, model: &Model, stages_slices: &[(Stage, Vec<SliceKind>)]) {
        for j in 0..self.caps.len() {
            for (stage, slices) in stages_slices {
                self.weights[j] += slice_weight_bytes(model, *stage, &slices[j]);
                self.peak_act[j] =
                    self.peak_act[j].max(slice_activation_bytes(model, *stage, &slices[j]));
            }
        }
    }
}

/// Slices a `Single(i)` segment would assign.
fn single_slices(model: &Model, cluster: &Cluster, i: usize) -> Vec<(Stage, Vec<SliceKind>)> {
    let stage = model.stages()[i];
    let slices = match model.ops[stage.op_idx].kind {
        OpKind::Conv2d { .. } => row_ranges(model, stage, cluster)
            .into_iter()
            .map(|(start, count)| {
                if count == 0 {
                    SliceKind::Idle
                } else {
                    SliceKind::Rows { start, count }
                }
            })
            .collect(),
        _ => vec![SliceKind::Replicate; cluster.m()],
    };
    vec![(stage, slices)]
}

/// Slices a `Pair(i)` segment would assign.
fn pair_slices(model: &Model, cluster: &Cluster, i: usize) -> Vec<(Stage, Vec<SliceKind>)> {
    let stages = model.stages();
    let (sa, sb) = (stages[i], stages[i + 1]);
    vec![
        (sa, oc_slices(model, sa, cluster)),
        (sb, ic_slices_aligned(model, sa, sb, cluster)),
    ]
}

/// Run Algorithm 1. Returns the segmentation `Γ`.
pub fn greedy(model: &Model, cluster: &Cluster) -> Vec<Segment> {
    let stages = model.stages();
    let n = stages.len();
    let mut segments = Vec::new();
    let mut tag = BoundaryTag::Rep; // the input image is replicated
    let mut mem = MemTracker::new(cluster);
    let mut i = 0;
    while i < n {
        let can_pair = i + 1 < n && pairable(model, stages[i], stages[i + 1]);
        let take_pair = if can_pair {
            let t_iop = pair_iop_cost_vs(model, cluster, i, tag);
            let t_co = pair_coedge_cost_vs(model, cluster, i, tag);
            if t_iop <= t_co {
                true
            } else {
                // eq. (1): a single that overflows memory forces the pair.
                let s = single_slices(model, cluster, i);
                !mem.feasible_with(model, &s)
                    && mem.feasible_with(model, &pair_slices(model, cluster, i))
            }
        } else {
            false
        };
        if take_pair {
            mem.commit(model, &pair_slices(model, cluster, i));
            segments.push(Segment::Pair(i));
            tag = BoundaryTag::Partial;
            i += 2;
        } else {
            mem.commit(model, &single_slices(model, cluster, i));
            let (_, next_tag) = single_cost_exact(model, cluster, i, tag);
            segments.push(Segment::Single(i));
            tag = next_tag;
            i += 1;
        }
    }
    segments
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::profiles;
    use crate::model::zoo;
    use crate::partition::plan::validate_segments;

    #[test]
    fn covers_all_stages_in_order() {
        let cluster = profiles::paper_default();
        for m in zoo::all_models() {
            let segs = greedy(&m, &cluster);
            validate_segments(&segs, m.stages().len()).unwrap();
        }
    }

    #[test]
    fn lenet_pairs_where_it_profits() {
        // LeNet's activations are tiny: pairing the conv stages removes
        // the halo + allgather traffic for one cheap reduce. At least one
        // pair must form.
        let m = zoo::lenet();
        let segs = greedy(&m, &profiles::paper_default());
        let pairs = segs.iter().filter(|s| matches!(s, Segment::Pair(_))).count();
        assert!(pairs >= 1, "{segs:?}");
    }

    #[test]
    fn vgg_keeps_early_convs_single() {
        // VGG's early convs have huge activations; Algorithm 1 should
        // leave them CoEdge-partitioned.
        let m = zoo::vgg11();
        let segs = greedy(&m, &profiles::paper_default());
        assert!(matches!(segs[0], Segment::Single(0)), "{segs:?}");
    }

    #[test]
    fn alexnet_pairs_the_classifier_not_the_convs() {
        let m = zoo::alexnet();
        let segs = greedy(&m, &profiles::paper_default());
        // conv2..conv5 stay single (stages 1..4); some FC pair exists.
        for s in &segs {
            if let Segment::Pair(i) = s {
                assert!(*i >= 4, "unexpected conv pair at {i}: {segs:?}");
            }
        }
        assert!(
            segs.iter().any(|s| matches!(s, Segment::Pair(_))),
            "{segs:?}"
        );
    }

    #[test]
    fn memory_pressure_forces_fc_pairing() {
        // eq. (1): on memory-tight devices, CoEdge-style replicated FC
        // singles do not fit, so Algorithm 1 must IOP-pair the classifier
        // — this is the configuration that reproduces the paper's Fig. 5
        // LeNet memory saving (~50% vs CoEdge).
        use crate::cost::memory::plan_memory;
        use crate::partition::iop::plan_iop_with_segments;
        let m = zoo::lenet();
        // LeNet full weights ≈ 247 KB; give each device 160 KB.
        let tight = crate::device::profiles::tiny_memory(3, 160 * 1024);
        let segs = greedy(&m, &tight);
        validate_segments(&segs, m.stages().len()).unwrap();
        let fc_start = m
            .stages()
            .iter()
            .position(|s| m.ops[s.op_idx].kind_tag() == "fc")
            .unwrap();
        assert!(
            segs.iter()
                .any(|s| matches!(s, Segment::Pair(i) if *i + 1 >= fc_start)),
            "{segs:?}"
        );
        // And the resulting plan's peak memory beats CoEdge's by ~half.
        let plan = plan_iop_with_segments(&m, &tight, &segs);
        let iop_peak = plan_memory(&m, &plan).peak_footprint();
        let co = crate::partition::coedge::plan_coedge(&m, &tight);
        let co_peak = plan_memory(&m, &co).peak_footprint();
        assert!(
            (iop_peak as f64) < 0.6 * co_peak as f64,
            "iop={iop_peak} coedge={co_peak}"
        );
    }

    #[test]
    fn zero_t_est_still_valid() {
        let m = zoo::alexnet();
        let c = profiles::paper_with_t_est(0.0);
        let segs = greedy(&m, &c);
        validate_segments(&segs, m.stages().len()).unwrap();
    }
}
