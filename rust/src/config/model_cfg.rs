//! JSON → `Model` (custom CNN definitions).

use crate::model::{Model, Op, OpKind, Shape};
use crate::util::json::Json;
use anyhow::{anyhow, bail, Result};

/// Build a model from its JSON spec. Input channels of conv/dense ops are
/// inferred from the running shape.
pub fn model_from_json(j: &Json) -> Result<Model> {
    let name = j
        .get("name")
        .as_str()
        .ok_or_else(|| anyhow!("model spec needs a 'name'"))?
        .to_string();
    let input = parse_shape(j.get("input"))?;
    let ops_json = j
        .get("ops")
        .as_arr()
        .ok_or_else(|| anyhow!("model spec needs 'ops'"))?;

    let mut ops: Vec<Op> = Vec::with_capacity(ops_json.len());
    let mut cur = input;
    for (i, oj) in ops_json.iter().enumerate() {
        let ty = oj
            .get("type")
            .as_str()
            .ok_or_else(|| anyhow!("op {i}: missing 'type'"))?;
        let name_of = |d: &str| {
            oj.get("name")
                .as_str()
                .map(|s| s.to_string())
                .unwrap_or_else(|| format!("{d}{i}"))
        };
        let op = match ty {
            "conv" => {
                let c_out = req_usize(oj, "c_out", i)?;
                let k = req_usize(oj, "k", i)?;
                let stride = opt_usize(oj, "stride", 1)?;
                let pad = opt_usize(oj, "pad", 0)?;
                let relu = oj.get("relu").as_bool().unwrap_or(true);
                Op::new(
                    name_of("conv"),
                    OpKind::Conv2d {
                        c_in: cur.c,
                        c_out,
                        k_h: k,
                        k_w: k,
                        stride,
                        pad,
                        relu,
                    },
                )
            }
            "dense" => {
                let c_out = req_usize(oj, "c_out", i)?;
                let relu = oj.get("relu").as_bool().unwrap_or(true);
                Op::new(
                    name_of("fc"),
                    OpKind::Dense {
                        c_in: cur.elems(),
                        c_out,
                        relu,
                    },
                )
            }
            "maxpool" => {
                let k = req_usize(oj, "k", i)?;
                let stride = opt_usize(oj, "stride", k)?;
                Op::new(name_of("pool"), OpKind::MaxPool { k, stride })
            }
            "flatten" => Op::new(name_of("flatten"), OpKind::Flatten),
            "relu" => Op::new(name_of("relu"), OpKind::Relu),
            other => bail!("op {i}: unknown type '{other}'"),
        };
        // Dense after conv without an explicit flatten: insert one (the
        // common shorthand).
        if matches!(op.kind, OpKind::Dense { .. }) && cur.h * cur.w > 1 {
            let had_flatten = ops
                .last()
                .map(|o| matches!(o.kind, OpKind::Flatten))
                .unwrap_or(false);
            if !had_flatten {
                let f = Op::new(format!("flatten{i}"), OpKind::Flatten);
                cur = f.out_shape(cur);
                ops.push(f);
            }
        }
        cur = op.out_shape(cur);
        ops.push(op);
    }
    Ok(Model::new(name, input, ops))
}

fn parse_shape(j: &Json) -> Result<Shape> {
    let a = j.as_arr().ok_or_else(|| anyhow!("'input' must be [c, h, w]"))?;
    if a.len() != 3 {
        bail!("'input' must have 3 dims");
    }
    let d = |i: usize| {
        a[i].as_usize()
            .ok_or_else(|| anyhow!("'input' dims must be positive ints"))
    };
    Ok(Shape::new(d(0)?, d(1)?, d(2)?))
}

fn req_usize(j: &Json, key: &str, op: usize) -> Result<usize> {
    j.get(key)
        .as_usize()
        .ok_or_else(|| anyhow!("op {op}: missing/invalid '{key}'"))
}

fn opt_usize(j: &Json, key: &str, default: usize) -> Result<usize> {
    match j.get(key) {
        Json::Null => Ok(default),
        v => v
            .as_usize()
            .ok_or_else(|| anyhow!("invalid '{key}' (must be a positive int)")),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec(s: &str) -> Result<Model> {
        model_from_json(&Json::parse(s).unwrap())
    }

    const TINY: &str = r#"{
        "name": "tiny",
        "input": [3, 16, 16],
        "ops": [
            {"type": "conv", "name": "c1", "c_out": 4, "k": 3, "pad": 1},
            {"type": "maxpool", "k": 2},
            {"type": "conv", "name": "c2", "c_out": 8, "k": 3, "pad": 1},
            {"type": "maxpool", "k": 2},
            {"type": "dense", "name": "out", "c_out": 10, "relu": false}
        ]
    }"#;

    #[test]
    fn parses_and_infers_channels() {
        let m = spec(TINY).unwrap();
        assert_eq!(m.name, "tiny");
        // implicit flatten inserted before the dense
        assert_eq!(m.count_kind("flatten"), 1);
        assert_eq!(*m.shapes().last().unwrap(), crate::model::Shape::vector(10));
        // c_in inferred: conv2 gets 4 input channels
        assert_eq!(m.ops.iter().find(|o| o.name == "c2").unwrap().c_in(), Some(4));
        // dense c_in inferred: 8 * 4 * 4
        assert_eq!(m.ops.iter().find(|o| o.name == "out").unwrap().c_in(), Some(128));
    }

    #[test]
    fn custom_model_plans_and_executes() {
        use crate::device::profiles;
        use crate::exec::compute::centralized_inference;
        use crate::exec::weights::{model_input, WeightBundle};
        use crate::exec::{run_plan, ExecOptions};
        use crate::partition::Strategy;
        let m = spec(TINY).unwrap();
        let cluster = profiles::paper_default();
        let wb = WeightBundle::generate(&m);
        let expect = centralized_inference(&m, &wb, &model_input(&m));
        for s in Strategy::all() {
            let plan = crate::pipeline::plan(&m, &cluster, s);
            plan.validate(&m).unwrap();
            let got = run_plan(&m, &plan, &ExecOptions::default()).unwrap();
            assert!(got.output.allclose(&expect, 1e-4, 1e-5), "{}", s.name());
        }
    }

    #[test]
    fn rejects_bad_specs() {
        assert!(spec(r#"{"input": [1,8,8], "ops": []}"#).is_err()); // no name
        assert!(spec(r#"{"name": "x", "input": [1, 8], "ops": []}"#).is_err());
        assert!(
            spec(r#"{"name": "x", "input": [1,8,8], "ops": [{"type": "warp"}]}"#).is_err()
        );
        assert!(
            spec(r#"{"name": "x", "input": [1,8,8], "ops": [{"type": "conv", "k": 3}]}"#)
                .is_err()
        ); // missing c_out
    }

    #[test]
    fn zoo_equivalence_via_config() {
        // vgg_mini expressed as a config equals the built-in builder.
        let cfg = r#"{
            "name": "vgg_mini",
            "input": [3, 32, 32],
            "ops": [
                {"type": "conv", "name": "conv1", "c_out": 8, "k": 3, "pad": 1},
                {"type": "maxpool", "name": "pool1", "k": 2},
                {"type": "conv", "name": "conv2", "c_out": 16, "k": 3, "pad": 1},
                {"type": "maxpool", "name": "pool2", "k": 2},
                {"type": "conv", "name": "conv3", "c_out": 32, "k": 3, "pad": 1},
                {"type": "maxpool", "name": "pool3", "k": 2},
                {"type": "flatten", "name": "flatten"},
                {"type": "dense", "name": "fc1", "c_out": 64},
                {"type": "dense", "name": "fc2", "c_out": 10, "relu": false}
            ]
        }"#;
        let a = spec(cfg).unwrap();
        let b = crate::model::zoo::vgg_mini();
        assert_eq!(a.shapes(), b.shapes());
        assert_eq!(a.total_flops(), b.total_flops());
        assert_eq!(a.total_weight_bytes(), b.total_weight_bytes());
    }
}
