//! Config files: define custom models and clusters as JSON, so the
//! framework is usable beyond the built-in zoo (the "composable model
//! definition" a downstream user needs).
//!
//! Model spec (`examples/configs/custom_cnn.json` ships one):
//!
//! ```json
//! {
//!   "name": "custom",
//!   "input": [3, 32, 32],
//!   "ops": [
//!     {"type": "conv",    "name": "c1", "c_out": 8, "k": 3, "stride": 1,
//!      "pad": 1, "relu": true},
//!     {"type": "maxpool", "name": "p1", "k": 2, "stride": 2},
//!     {"type": "flatten"},
//!     {"type": "dense",   "name": "f1", "c_out": 10, "relu": false}
//!   ]
//! }
//! ```
//!
//! `c_in` is inferred from the running shape, so specs stay minimal and
//! cannot go out of sync.
//!
//! Cluster spec: either the shared form
//! `{"devices": 3, "gflops": 0.6, "mem_mib": 512, "bandwidth_mbps": 50,
//!   "t_est_ms": 4}` or per-device
//! `{"devices": [{"gflops": 1.2, "mem_mib": 1024}, ...], ...}`.

pub mod cluster_cfg;
pub mod model_cfg;

pub use cluster_cfg::{
    cluster_from_json, deploy_from_json, fault_plan_from_json, link_shape_from_json, DeploySpec,
    FaultPlan, KillSpec, LinkFault, LinkShape, ShapeOverride, StallSpec,
};
pub use model_cfg::model_from_json;

use crate::device::Cluster;
use crate::model::Model;
use anyhow::{anyhow, Context, Result};

/// Load a model spec from a JSON file.
pub fn load_model(path: &str) -> Result<Model> {
    let text = std::fs::read_to_string(path).with_context(|| format!("reading {path}"))?;
    let json = crate::util::json::Json::parse(&text).map_err(|e| anyhow!("{path}: {e}"))?;
    model_from_json(&json)
}

/// Load a cluster spec from a JSON file.
pub fn load_cluster(path: &str) -> Result<Cluster> {
    let text = std::fs::read_to_string(path).with_context(|| format!("reading {path}"))?;
    let json = crate::util::json::Json::parse(&text).map_err(|e| anyhow!("{path}: {e}"))?;
    cluster_from_json(&json)
}

/// Load a fault-injection plan from a JSON file (see [`FaultPlan`] for
/// the schema; `iop serve --fault-plan` is the consumer).
pub fn load_fault_plan(path: &str) -> Result<FaultPlan> {
    let text = std::fs::read_to_string(path).with_context(|| format!("reading {path}"))?;
    let json = crate::util::json::Json::parse(&text).map_err(|e| anyhow!("{path}: {e}"))?;
    fault_plan_from_json(&json)
}

/// Load a deployment spec — worker addresses and/or link shape — from a
/// JSON file (`iop exec|serve --deploy` is the consumer).
pub fn load_deploy(path: &str) -> Result<DeploySpec> {
    let text = std::fs::read_to_string(path).with_context(|| format!("reading {path}"))?;
    let json = crate::util::json::Json::parse(&text).map_err(|e| anyhow!("{path}: {e}"))?;
    deploy_from_json(&json)
}
