//! JSON → `Cluster` (testbed definitions) and → [`FaultPlan`]
//! (fault-injection schedules for the serving harness).

use crate::device::{Cluster, Device};
use crate::util::json::Json;
use anyhow::{anyhow, Result};

/// Build a cluster from its JSON spec. `devices` is either a count
/// (homogeneous, with shared `gflops`/`mem_mib`) or an array of
/// per-device `{gflops, mem_mib}` objects.
pub fn cluster_from_json(j: &Json) -> Result<Cluster> {
    let bandwidth_mbps = j.get("bandwidth_mbps").as_f64().unwrap_or(50.0);
    let t_est_ms = j.get("t_est_ms").as_f64().unwrap_or(4.0);

    let devices = match j.get("devices") {
        Json::Num(_) => {
            let m = j
                .get("devices")
                .as_usize()
                .ok_or_else(|| anyhow!("'devices' count must be a positive int"))?;
            let gflops = j.get("gflops").as_f64().unwrap_or(0.6);
            let mem_mib = j.get("mem_mib").as_f64().unwrap_or(512.0);
            vec![Device::new(gflops * 1e9, (mem_mib * 1048576.0) as u64); m]
        }
        Json::Arr(list) => list
            .iter()
            .enumerate()
            .map(|(i, d)| {
                let gflops = d
                    .get("gflops")
                    .as_f64()
                    .ok_or_else(|| anyhow!("device {i}: missing 'gflops'"))?;
                let mem_mib = d.get("mem_mib").as_f64().unwrap_or(512.0);
                Ok(Device::new(gflops * 1e9, (mem_mib * 1048576.0) as u64))
            })
            .collect::<Result<Vec<_>>>()?,
        _ => return Err(anyhow!("cluster spec needs 'devices' (count or array)")),
    };
    if devices.is_empty() {
        return Err(anyhow!("cluster needs at least one device"));
    }
    Ok(Cluster::new(
        devices,
        bandwidth_mbps * 1e6 / 8.0,
        t_est_ms * 1e-3,
    ))
}

/// A reproducible fault-injection schedule for the real execution
/// harness (`exec::transport::FaultTransport`): per-link delay/drop and
/// per-device kill triggers, all derived from one seed so a chaos run
/// replays bit-for-bit.
///
/// JSON schema (`iop serve --fault-plan plan.json`):
///
/// ```json
/// {
///   "seed": 7,
///   "recv_timeout_ms": 2000,
///   "links": [{"from": 0, "to": 1, "delay_ms": 2, "drop_prob": 0.5}],
///   "kills": [{"dev": 1, "at_req": 10, "at_stage": 3}],
///   "stalls": [{"dev": 1, "after_ms": 500, "duration_ms": 800}]
/// }
/// ```
///
/// Device ids always refer to the *original* cluster indices — after a
/// recovery re-plan the surviving workers keep their original ids for
/// fault lookups, so a schedule means the same thing across epochs.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct FaultPlan {
    /// Base seed for the per-device drop RNG streams.
    pub seed: u64,
    /// Per-receive deadline for every tagged receive in the session
    /// (`None` = the harness default). Blocking past this deadline is a
    /// protocol error — the waiting worker reports a `RecvDeadline`
    /// instead of hanging.
    pub recv_timeout_ms: Option<u64>,
    /// Directed per-link faults; absent links are perfect.
    pub links: Vec<LinkFault>,
    /// Device kill triggers.
    pub kills: Vec<KillSpec>,
    /// Control-link stall windows (hang/partition injection for the
    /// liveness layer); only meaningful on socket sessions.
    pub stalls: Vec<StallSpec>,
}

/// Faults on one directed link `from -> to`.
#[derive(Debug, Clone, PartialEq)]
pub struct LinkFault {
    pub from: usize,
    pub to: usize,
    /// Added latency per message, milliseconds (applied sender-side).
    pub delay_ms: f64,
    /// Probability each message is silently lost on the wire, in [0, 1].
    pub drop_prob: f64,
}

/// Kill device `dev` when it reaches request `at_req` (session-global
/// [`crate::exec::ReqId`]) at stage `at_stage` (default: the first
/// stage). The trigger fires once: the worker reports a `WorkerKilled`
/// error and exits, abandoning the wire protocol mid-request — exactly
/// what a crashed device looks like to its peers.
#[derive(Debug, Clone, PartialEq)]
pub struct KillSpec {
    pub dev: usize,
    pub at_req: usize,
    pub at_stage: Option<usize>,
}

/// Simulate a hung or partitioned worker: starting `after_ms` after the
/// epoch comes up, the coordinator-side keepalive treats device `dev`'s
/// control link as silent (heartbeats neither sent nor heard) for
/// `duration_ms` (`None` = forever — a wedged process). A stall shorter
/// than the liveness grace window resumes the live epoch; a longer one
/// escalates to the dead-worker signal exactly like a SIGSTOP'd process.
#[derive(Debug, Clone, PartialEq)]
pub struct StallSpec {
    pub dev: usize,
    pub after_ms: u64,
    pub duration_ms: Option<u64>,
}

impl FaultPlan {
    /// Fault on the directed link `from -> to`, if any.
    pub fn link(&self, from: usize, to: usize) -> Option<&LinkFault> {
        self.links.iter().find(|l| l.from == from && l.to == to)
    }

    /// Kill triggers for one device.
    pub fn kills_for(&self, dev: usize) -> Vec<&KillSpec> {
        self.kills.iter().filter(|k| k.dev == dev).collect()
    }

    /// Check every device reference against a cluster of `m` devices.
    pub fn validate(&self, m: usize) -> Result<()> {
        for l in &self.links {
            if l.from >= m || l.to >= m {
                return Err(anyhow!(
                    "fault plan link {}->{} references a device outside the cluster (m={m})",
                    l.from,
                    l.to
                ));
            }
            if l.from == l.to {
                return Err(anyhow!("fault plan link {}->{} is a self-loop", l.from, l.to));
            }
        }
        for k in &self.kills {
            if k.dev >= m {
                return Err(anyhow!(
                    "fault plan kills device {} outside the cluster (m={m})",
                    k.dev
                ));
            }
        }
        for s in &self.stalls {
            if s.dev >= m {
                return Err(anyhow!(
                    "fault plan stalls device {} outside the cluster (m={m})",
                    s.dev
                ));
            }
            if s.duration_ms == Some(0) {
                return Err(anyhow!(
                    "fault plan stall on device {}: duration_ms must be > 0 (omit it for a permanent stall)",
                    s.dev
                ));
            }
        }
        Ok(())
    }
}

/// Build a [`FaultPlan`] from its JSON spec (see the struct docs for the
/// schema). Unknown fields are ignored; malformed entries error.
pub fn fault_plan_from_json(j: &Json) -> Result<FaultPlan> {
    let seed = j.get("seed").as_f64().unwrap_or(0.0) as u64;
    let recv_timeout_ms = j.get("recv_timeout_ms").as_f64().map(|v| v as u64);
    let mut links = Vec::new();
    if let Json::Arr(list) = j.get("links") {
        for (i, l) in list.iter().enumerate() {
            let from = l
                .get("from")
                .as_usize()
                .ok_or_else(|| anyhow!("fault plan link {i}: missing 'from'"))?;
            let to = l
                .get("to")
                .as_usize()
                .ok_or_else(|| anyhow!("fault plan link {i}: missing 'to'"))?;
            let delay_ms = l.get("delay_ms").as_f64().unwrap_or(0.0);
            let drop_prob = l.get("drop_prob").as_f64().unwrap_or(0.0);
            if delay_ms < 0.0 {
                return Err(anyhow!("fault plan link {i}: delay_ms must be >= 0"));
            }
            if !(0.0..=1.0).contains(&drop_prob) {
                return Err(anyhow!("fault plan link {i}: drop_prob must be in [0, 1]"));
            }
            links.push(LinkFault {
                from,
                to,
                delay_ms,
                drop_prob,
            });
        }
    }
    let mut kills = Vec::new();
    if let Json::Arr(list) = j.get("kills") {
        for (i, k) in list.iter().enumerate() {
            let dev = k
                .get("dev")
                .as_usize()
                .ok_or_else(|| anyhow!("fault plan kill {i}: missing 'dev'"))?;
            let at_req = k
                .get("at_req")
                .as_usize()
                .ok_or_else(|| anyhow!("fault plan kill {i}: missing 'at_req'"))?;
            let at_stage = k.get("at_stage").as_usize();
            kills.push(KillSpec {
                dev,
                at_req,
                at_stage,
            });
        }
    }
    let mut stalls = Vec::new();
    if let Json::Arr(list) = j.get("stalls") {
        for (i, s) in list.iter().enumerate() {
            let dev = s
                .get("dev")
                .as_usize()
                .ok_or_else(|| anyhow!("fault plan stall {i}: missing 'dev'"))?;
            let after_ms = s
                .get("after_ms")
                .as_f64()
                .ok_or_else(|| anyhow!("fault plan stall {i}: missing 'after_ms'"))?
                as u64;
            let duration_ms = s.get("duration_ms").as_f64().map(|v| v as u64);
            stalls.push(StallSpec { dev, after_ms, duration_ms });
        }
    }
    Ok(FaultPlan {
        seed,
        recv_timeout_ms,
        links,
        kills,
        stalls,
    })
}

/// The modeled shape of the shared medium for
/// `exec::transport::ShapedTransport`: default per-message latency and
/// bandwidth, with optional per-directed-link overrides. Device ids are
/// original cluster indices, like [`FaultPlan`].
///
/// JSON schema (standalone, or the `"link"` key of a deployment spec):
///
/// ```json
/// {
///   "latency_ms": 4,
///   "mbps": 50,
///   "links": [{"from": 0, "to": 1, "latency_ms": 8, "mbps": 20}]
/// }
/// ```
#[derive(Debug, Clone, PartialEq)]
pub struct LinkShape {
    /// Default per-message latency, milliseconds.
    pub latency_ms: f64,
    /// Default link bandwidth, megabits per second.
    pub mbps: f64,
    /// Directed overrides; absent links use the defaults.
    pub links: Vec<ShapeOverride>,
}

/// Shape override for one directed link `from -> to`.
#[derive(Debug, Clone, PartialEq)]
pub struct ShapeOverride {
    pub from: usize,
    pub to: usize,
    pub latency_ms: f64,
    pub mbps: f64,
}

impl LinkShape {
    pub fn new(latency_ms: f64, mbps: f64) -> LinkShape {
        LinkShape { latency_ms, mbps, links: Vec::new() }
    }

    /// `(latency_secs, bytes_per_sec)` for the directed link `from -> to`
    /// (original device ids).
    pub fn params(&self, from: usize, to: usize) -> (f64, f64) {
        let (ms, mbps) = self
            .links
            .iter()
            .find(|l| l.from == from && l.to == to)
            .map(|l| (l.latency_ms, l.mbps))
            .unwrap_or((self.latency_ms, self.mbps));
        (ms * 1e-3, mbps * 1e6 / 8.0)
    }

    pub fn validate(&self, m: usize) -> Result<()> {
        if self.mbps <= 0.0 || self.latency_ms < 0.0 {
            return Err(anyhow!(
                "link shape needs mbps > 0 and latency_ms >= 0 (got {} / {})",
                self.mbps,
                self.latency_ms
            ));
        }
        for l in &self.links {
            if l.from >= m || l.to >= m {
                return Err(anyhow!(
                    "link shape override {}->{} references a device outside the cluster (m={m})",
                    l.from,
                    l.to
                ));
            }
            if l.mbps <= 0.0 || l.latency_ms < 0.0 {
                return Err(anyhow!(
                    "link shape override {}->{} needs mbps > 0 and latency_ms >= 0",
                    l.from,
                    l.to
                ));
            }
        }
        Ok(())
    }
}

/// Build a [`LinkShape`] from its JSON spec (see the struct docs).
pub fn link_shape_from_json(j: &Json) -> Result<LinkShape> {
    let latency_ms = j.get("latency_ms").as_f64().unwrap_or(4.0);
    let mbps = j.get("mbps").as_f64().unwrap_or(50.0);
    let mut links = Vec::new();
    if let Json::Arr(list) = j.get("links") {
        for (i, l) in list.iter().enumerate() {
            let from = l
                .get("from")
                .as_usize()
                .ok_or_else(|| anyhow!("link shape override {i}: missing 'from'"))?;
            let to = l
                .get("to")
                .as_usize()
                .ok_or_else(|| anyhow!("link shape override {i}: missing 'to'"))?;
            links.push(ShapeOverride {
                from,
                to,
                latency_ms: l.get("latency_ms").as_f64().unwrap_or(latency_ms),
                mbps: l.get("mbps").as_f64().unwrap_or(mbps),
            });
        }
    }
    let s = LinkShape { latency_ms, mbps, links };
    if s.mbps <= 0.0 || s.latency_ms < 0.0 {
        return Err(anyhow!("link shape needs mbps > 0 and latency_ms >= 0"));
    }
    Ok(s)
}

/// A deployment spec: where the worker processes listen, and optionally
/// the modeled shape of the links between them — the file form of
/// `iop serve --workers ... ` / `--transport shaped` flags.
///
/// ```json
/// {
///   "workers": ["unix:/tmp/iop-w0.sock", "192.168.1.20:7070"],
///   "link": {"latency_ms": 4, "mbps": 50}
/// }
/// ```
#[derive(Debug, Clone, Default, PartialEq)]
pub struct DeploySpec {
    /// One listen address per cluster device, in device order.
    pub workers: Vec<String>,
    pub link: Option<LinkShape>,
}

/// Build a [`DeploySpec`] from its JSON spec. Addresses are validated
/// syntactically here so a typo fails at config load, not mid-dial.
pub fn deploy_from_json(j: &Json) -> Result<DeploySpec> {
    let mut workers = Vec::new();
    if let Json::Arr(list) = j.get("workers") {
        for (i, w) in list.iter().enumerate() {
            let s = w
                .as_str()
                .ok_or_else(|| anyhow!("deploy spec worker {i}: must be an address string"))?;
            crate::exec::wire::Addr::parse(s).map_err(|e| anyhow!("deploy spec worker {i}: {e}"))?;
            workers.push(s.to_string());
        }
    }
    let link = match j.get("link") {
        Json::Null => None,
        l => Some(link_shape_from_json(l)?),
    };
    if workers.is_empty() && link.is_none() {
        return Err(anyhow!("deploy spec needs 'workers' and/or 'link'"));
    }
    Ok(DeploySpec { workers, link })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn homogeneous_shorthand() {
        let j = Json::parse(
            r#"{"devices": 3, "gflops": 0.6, "mem_mib": 512,
                "bandwidth_mbps": 50, "t_est_ms": 4}"#,
        )
        .unwrap();
        let c = cluster_from_json(&j).unwrap();
        assert_eq!(c, crate::device::profiles::paper_default());
    }

    #[test]
    fn per_device_list() {
        let j = Json::parse(
            r#"{"devices": [{"gflops": 1.2, "mem_mib": 1024},
                             {"gflops": 0.6},
                             {"gflops": 0.3, "mem_mib": 256}],
                "bandwidth_mbps": 50, "t_est_ms": 4}"#,
        )
        .unwrap();
        let c = cluster_from_json(&j).unwrap();
        assert_eq!(c.m(), 3);
        assert_eq!(c.devices[0].flops_per_sec, 1.2e9);
        assert_eq!(c.devices[1].mem_bytes, 512 << 20); // default
    }

    #[test]
    fn defaults_applied() {
        let c = cluster_from_json(&Json::parse(r#"{"devices": 2}"#).unwrap()).unwrap();
        assert_eq!(c.m(), 2);
        assert_eq!(c.bandwidth_bps, 50e6 / 8.0);
    }

    #[test]
    fn rejects_bad() {
        assert!(cluster_from_json(&Json::parse(r#"{"devices": []}"#).unwrap()).is_err());
        assert!(cluster_from_json(&Json::parse(r#"{}"#).unwrap()).is_err());
        assert!(cluster_from_json(
            &Json::parse(r#"{"devices": [{"mem_mib": 5}]}"#).unwrap()
        )
        .is_err());
    }

    #[test]
    fn fault_plan_full_schema() {
        let j = Json::parse(
            r#"{"seed": 7, "recv_timeout_ms": 2000,
                "links": [{"from": 0, "to": 1, "delay_ms": 2.5, "drop_prob": 0.5}],
                "kills": [{"dev": 1, "at_req": 10, "at_stage": 3},
                           {"dev": 2, "at_req": 4}],
                "stalls": [{"dev": 0, "after_ms": 500, "duration_ms": 800},
                            {"dev": 2, "after_ms": 100}]}"#,
        )
        .unwrap();
        let p = fault_plan_from_json(&j).unwrap();
        assert_eq!(p.seed, 7);
        assert_eq!(p.recv_timeout_ms, Some(2000));
        assert_eq!(p.links.len(), 1);
        assert_eq!(p.link(0, 1).unwrap().drop_prob, 0.5);
        assert!(p.link(1, 0).is_none());
        assert_eq!(p.kills.len(), 2);
        assert_eq!(p.kills_for(1)[0].at_stage, Some(3));
        assert_eq!(p.kills_for(2)[0].at_stage, None);
        assert_eq!(p.stalls.len(), 2);
        assert_eq!(p.stalls[0].duration_ms, Some(800));
        assert_eq!(p.stalls[1].duration_ms, None, "omitted duration = permanent stall");
        p.validate(3).unwrap();
    }

    #[test]
    fn fault_plan_defaults_and_empty() {
        let p = fault_plan_from_json(&Json::parse("{}").unwrap()).unwrap();
        assert_eq!(p, FaultPlan::default());
        assert_eq!(p.recv_timeout_ms, None);
        p.validate(1).unwrap();
    }

    #[test]
    fn fault_plan_rejects_malformed() {
        for bad in [
            r#"{"links": [{"from": 0}]}"#,
            r#"{"links": [{"from": 0, "to": 1, "drop_prob": 1.5}]}"#,
            r#"{"links": [{"from": 0, "to": 1, "delay_ms": -1}]}"#,
            r#"{"kills": [{"at_req": 3}]}"#,
            r#"{"kills": [{"dev": 1}]}"#,
            r#"{"stalls": [{"after_ms": 100}]}"#,
            r#"{"stalls": [{"dev": 1}]}"#,
        ] {
            assert!(
                fault_plan_from_json(&Json::parse(bad).unwrap()).is_err(),
                "should reject: {bad}"
            );
        }
    }

    #[test]
    fn link_shape_schema_and_lookup() {
        let j = Json::parse(
            r#"{"latency_ms": 4, "mbps": 50,
                "links": [{"from": 0, "to": 1, "latency_ms": 8, "mbps": 20}]}"#,
        )
        .unwrap();
        let s = link_shape_from_json(&j).unwrap();
        let (lat, bps) = s.params(0, 1);
        assert_eq!((lat, bps), (8e-3, 20e6 / 8.0));
        let (lat, bps) = s.params(1, 0);
        assert_eq!((lat, bps), (4e-3, 50e6 / 8.0), "reverse direction uses defaults");
        s.validate(2).unwrap();
        assert!(s.validate(1).is_err(), "override names device outside the cluster");
        assert!(link_shape_from_json(&Json::parse(r#"{"mbps": 0}"#).unwrap()).is_err());
        assert!(link_shape_from_json(&Json::parse(r#"{"latency_ms": -1}"#).unwrap()).is_err());
        // defaults-only spec is fine
        let d = link_shape_from_json(&Json::parse("{}").unwrap()).unwrap();
        assert_eq!((d.latency_ms, d.mbps), (4.0, 50.0));
    }

    #[test]
    fn deploy_spec_schema() {
        let j = Json::parse(
            r#"{"workers": ["unix:/tmp/w0.sock", "127.0.0.1:7070"],
                "link": {"latency_ms": 2, "mbps": 100}}"#,
        )
        .unwrap();
        let d = deploy_from_json(&j).unwrap();
        assert_eq!(d.workers.len(), 2);
        assert_eq!(d.link.as_ref().unwrap().mbps, 100.0);
        // a bad address fails at load time
        assert!(deploy_from_json(
            &Json::parse(r#"{"workers": ["not-an-address"]}"#).unwrap()
        )
        .is_err());
        assert!(deploy_from_json(&Json::parse("{}").unwrap()).is_err());
    }

    #[test]
    fn fault_plan_validate_checks_device_range() {
        let p = fault_plan_from_json(
            &Json::parse(r#"{"kills": [{"dev": 3, "at_req": 0}]}"#).unwrap(),
        )
        .unwrap();
        assert!(p.validate(3).is_err());
        p.validate(4).unwrap();
        let l = fault_plan_from_json(
            &Json::parse(r#"{"links": [{"from": 0, "to": 0}]}"#).unwrap(),
        )
        .unwrap();
        assert!(l.validate(2).is_err(), "self-loop links are rejected");
        let s = fault_plan_from_json(
            &Json::parse(r#"{"stalls": [{"dev": 2, "after_ms": 0}]}"#).unwrap(),
        )
        .unwrap();
        assert!(s.validate(2).is_err(), "stall device outside the cluster");
        s.validate(3).unwrap();
        let z = fault_plan_from_json(
            &Json::parse(r#"{"stalls": [{"dev": 0, "after_ms": 0, "duration_ms": 0}]}"#).unwrap(),
        )
        .unwrap();
        assert!(z.validate(1).is_err(), "zero-duration stall is a typo, not a request");
    }
}
