//! JSON → `Cluster` (testbed definitions).

use crate::device::{Cluster, Device};
use crate::util::json::Json;
use anyhow::{anyhow, Result};

/// Build a cluster from its JSON spec. `devices` is either a count
/// (homogeneous, with shared `gflops`/`mem_mib`) or an array of
/// per-device `{gflops, mem_mib}` objects.
pub fn cluster_from_json(j: &Json) -> Result<Cluster> {
    let bandwidth_mbps = j.get("bandwidth_mbps").as_f64().unwrap_or(50.0);
    let t_est_ms = j.get("t_est_ms").as_f64().unwrap_or(4.0);

    let devices = match j.get("devices") {
        Json::Num(_) => {
            let m = j
                .get("devices")
                .as_usize()
                .ok_or_else(|| anyhow!("'devices' count must be a positive int"))?;
            let gflops = j.get("gflops").as_f64().unwrap_or(0.6);
            let mem_mib = j.get("mem_mib").as_f64().unwrap_or(512.0);
            vec![Device::new(gflops * 1e9, (mem_mib * 1048576.0) as u64); m]
        }
        Json::Arr(list) => list
            .iter()
            .enumerate()
            .map(|(i, d)| {
                let gflops = d
                    .get("gflops")
                    .as_f64()
                    .ok_or_else(|| anyhow!("device {i}: missing 'gflops'"))?;
                let mem_mib = d.get("mem_mib").as_f64().unwrap_or(512.0);
                Ok(Device::new(gflops * 1e9, (mem_mib * 1048576.0) as u64))
            })
            .collect::<Result<Vec<_>>>()?,
        _ => return Err(anyhow!("cluster spec needs 'devices' (count or array)")),
    };
    if devices.is_empty() {
        return Err(anyhow!("cluster needs at least one device"));
    }
    Ok(Cluster::new(
        devices,
        bandwidth_mbps * 1e6 / 8.0,
        t_est_ms * 1e-3,
    ))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn homogeneous_shorthand() {
        let j = Json::parse(
            r#"{"devices": 3, "gflops": 0.6, "mem_mib": 512,
                "bandwidth_mbps": 50, "t_est_ms": 4}"#,
        )
        .unwrap();
        let c = cluster_from_json(&j).unwrap();
        assert_eq!(c, crate::device::profiles::paper_default());
    }

    #[test]
    fn per_device_list() {
        let j = Json::parse(
            r#"{"devices": [{"gflops": 1.2, "mem_mib": 1024},
                             {"gflops": 0.6},
                             {"gflops": 0.3, "mem_mib": 256}],
                "bandwidth_mbps": 50, "t_est_ms": 4}"#,
        )
        .unwrap();
        let c = cluster_from_json(&j).unwrap();
        assert_eq!(c.m(), 3);
        assert_eq!(c.devices[0].flops_per_sec, 1.2e9);
        assert_eq!(c.devices[1].mem_bytes, 512 << 20); // default
    }

    #[test]
    fn defaults_applied() {
        let c = cluster_from_json(&Json::parse(r#"{"devices": 2}"#).unwrap()).unwrap();
        assert_eq!(c.m(), 2);
        assert_eq!(c.bandwidth_bps, 50e6 / 8.0);
    }

    #[test]
    fn rejects_bad() {
        assert!(cluster_from_json(&Json::parse(r#"{"devices": []}"#).unwrap()).is_err());
        assert!(cluster_from_json(&Json::parse(r#"{}"#).unwrap()).is_err());
        assert!(cluster_from_json(
            &Json::parse(r#"{"devices": [{"mem_mib": 5}]}"#).unwrap()
        )
        .is_err());
    }
}
