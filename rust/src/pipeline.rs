//! High-level façade: one call from (model, cluster, strategy) to a plan,
//! and from a plan to its cost — what the CLI, examples, and benches use.

use crate::cost::{self, PlanCost};
use crate::device::Cluster;
use crate::model::Model;
use crate::partition::{coedge, oc, Plan, Strategy};
use crate::segmentation;

/// Build the partition plan for a strategy (IOP uses the paper's greedy
/// Algorithm 1 internally).
pub fn plan(model: &Model, cluster: &Cluster, strategy: Strategy) -> Plan {
    match strategy {
        Strategy::Oc => oc::plan_oc(model, cluster),
        Strategy::CoEdge => coedge::plan_coedge(model, cluster),
        Strategy::Iop => segmentation::plan_iop(model, cluster),
    }
}

/// Price a plan under the analytic model (P1).
pub fn evaluate(model: &Model, cluster: &Cluster, plan: &Plan) -> PlanCost {
    cost::evaluate(model, cluster, plan)
}

/// Plan + evaluate in one step.
pub fn plan_and_evaluate(
    model: &Model,
    cluster: &Cluster,
    strategy: Strategy,
) -> (Plan, PlanCost) {
    let p = plan(model, cluster, strategy);
    let c = evaluate(model, cluster, &p);
    (p, c)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::profiles;
    use crate::model::zoo;

    #[test]
    fn all_strategies_produce_valid_plans() {
        let cluster = profiles::paper_default();
        for m in zoo::fig4_models() {
            for s in Strategy::all() {
                let p = plan(&m, &cluster, s);
                p.validate(&m).unwrap();
                let c = evaluate(&m, &cluster, &p);
                assert!(c.total_secs > 0.0);
            }
        }
    }

    #[test]
    fn fig4_ordering_iop_fastest_oc_slowest() {
        // The headline shape of Fig. 4: IOP < CoEdge < OC on all three
        // evaluation models.
        let cluster = profiles::paper_default();
        for m in zoo::fig4_models() {
            let oc = plan_and_evaluate(&m, &cluster, Strategy::Oc).1.total_secs;
            let co = plan_and_evaluate(&m, &cluster, Strategy::CoEdge).1.total_secs;
            let iop = plan_and_evaluate(&m, &cluster, Strategy::Iop).1.total_secs;
            assert!(iop < co, "{}: iop={iop} coedge={co}", m.name);
            assert!(co < oc, "{}: coedge={co} oc={oc}", m.name);
        }
    }

    #[test]
    fn fig5_ordering_coedge_worst_memory() {
        let cluster = profiles::paper_default();
        for m in zoo::fig4_models() {
            let co = plan_and_evaluate(&m, &cluster, Strategy::CoEdge)
                .1
                .memory
                .peak_footprint();
            let iop = plan_and_evaluate(&m, &cluster, Strategy::Iop)
                .1
                .memory
                .peak_footprint();
            assert!(iop < co, "{}: iop={iop} coedge={co}", m.name);
        }
    }
}
