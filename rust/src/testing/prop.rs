//! Seeded property testing: generators + a check loop with failure
//! minimization over the generator's size parameter.
//!
//! Usage:
//! ```ignore
//! use iop::testing::prop::{check, Gen};
//! check("split tiles exactly", 500, |g| {
//!     let n = g.usize_in(0, 4096);
//!     let shares = g.shares(g.usize_in(1, 8));
//!     let parts = proportional_split(n, &shares);
//!     prop_assert(parts.iter().sum::<usize>() == n, "must tile")
//! });
//! ```

use crate::util::prng::SplitMix64;

/// Generator handle passed to properties: seeded randomness plus a size
/// parameter the shrinker lowers on failure.
pub struct Gen {
    rng: SplitMix64,
    /// Current size cap (shrinking lowers this).
    pub size: usize,
}

impl Gen {
    pub fn new(seed: u64, size: usize) -> Self {
        Self {
            rng: SplitMix64::new(seed),
            size,
        }
    }

    pub fn u64(&mut self) -> u64 {
        self.rng.next_u64()
    }

    pub fn f32(&mut self) -> f32 {
        self.rng.next_f32()
    }

    /// Usize in `[lo, hi]`, additionally capped by the current size.
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        let hi = hi.min(lo + self.size);
        self.rng.range(lo, hi.max(lo))
    }

    /// A positive f64 in (0, scale].
    pub fn pos_f64(&mut self, scale: f64) -> f64 {
        (self.rng.next_f32() as f64).max(1e-6) * scale
    }

    /// `n` positive shares (device capabilities).
    pub fn shares(&mut self, n: usize) -> Vec<f64> {
        (0..n).map(|_| self.pos_f64(10.0)).collect()
    }

    /// Vector of f32 in [-1, 1).
    pub fn vec_f32(&mut self, n: usize) -> Vec<f32> {
        (0..n).map(|_| self.rng.next_symmetric(1.0)).collect()
    }

    pub fn bool(&mut self) -> bool {
        self.rng.next_u64() & 1 == 1
    }

    /// Pick one element of a slice.
    pub fn choose<'a, T>(&mut self, items: &'a [T]) -> &'a T {
        &items[self.rng.range(0, items.len() - 1)]
    }
}

/// Property outcome. Use [`prop_assert`] to build these.
pub type PropResult = Result<(), String>;

/// Assert helper for properties.
pub fn prop_assert(cond: bool, msg: impl Into<String>) -> PropResult {
    if cond {
        Ok(())
    } else {
        Err(msg.into())
    }
}

/// Run `cases` random cases of `property`. Panics with the smallest
/// reproduction found (seed + size) on failure.
pub fn check(name: &str, cases: usize, mut property: impl FnMut(&mut Gen) -> PropResult) {
    let base_seed = crate::util::prng::fnv1a(name);
    for case in 0..cases {
        let seed = base_seed.wrapping_add(case as u64);
        let size = 1 + (case * 128 / cases.max(1)); // grow sizes over the run
        let mut g = Gen::new(seed, size);
        if let Err(msg) = property(&mut g) {
            // Shrink: retry the same seed at smaller sizes, keep the
            // smallest size that still fails.
            let mut smallest = (size, msg);
            let mut s = size / 2;
            while s >= 1 {
                let mut g = Gen::new(seed, s);
                match property(&mut g) {
                    Err(m) => {
                        smallest = (s, m);
                        s /= 2;
                    }
                    Ok(()) => break,
                }
            }
            panic!(
                "property '{name}' failed (seed={seed}, size={}): {}",
                smallest.0, smallest.1
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check("u64 is u64", 200, |g| {
            let v = g.u64();
            prop_assert(v == v, "reflexive")
        });
    }

    #[test]
    #[should_panic(expected = "property 'always fails'")]
    fn failing_property_reports() {
        check("always fails", 10, |g| {
            let n = g.usize_in(0, 100);
            prop_assert(n > 100_000, "n too small (as designed)")
        });
    }

    #[test]
    fn generators_respect_bounds() {
        let mut g = Gen::new(42, 1000);
        for _ in 0..1000 {
            let v = g.usize_in(3, 9);
            assert!((3..=9).contains(&v));
            let f = g.pos_f64(5.0);
            assert!(f > 0.0 && f <= 5.0);
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let mut a = Gen::new(7, 10);
        let mut b = Gen::new(7, 10);
        for _ in 0..100 {
            assert_eq!(a.u64(), b.u64());
        }
    }
}
