//! In-house property-testing substrate (the offline build has no proptest).
//!
//! [`prop::check`] runs a property over many generated cases from a seeded
//! PRNG; on failure it retries progressively "smaller" seeds derived from
//! the failing case (shrinking-lite) and reports the smallest failure.

pub mod prop;
