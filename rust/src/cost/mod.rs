//! The analytical cost model — problem **P1** (paper eq. 6):
//! `Σ_i max_j (T^c_{i,j}) + T^g_i`, with compute priced by eq. (7)
//! (`compute`), communication by eq. (8) + establishment (`comm`), and the
//! memory constraint of eq. (1) (`memory`).

pub mod comm;
pub mod compute;
pub mod memory;

use crate::device::Cluster;
use crate::model::Model;
use crate::partition::plan::Plan;
use crate::util::json::Json;

/// Per-stage latency breakdown.
#[derive(Debug, Clone)]
pub struct StageCost {
    /// Which op heads the stage (index into `Model::ops`).
    pub op_idx: usize,
    /// Communication phase before the stage (shared medium, serialized).
    pub comm_secs: f64,
    /// Compute phase (max over devices).
    pub compute_secs: f64,
}

/// Full evaluation of a plan under the analytic model.
#[derive(Debug, Clone)]
pub struct PlanCost {
    pub stages: Vec<StageCost>,
    /// Final output assembly.
    pub final_comm_secs: f64,
    /// Total end-to-end inference latency (the Fig. 4 / Fig. 6 metric).
    pub total_secs: f64,
    /// Total compute share of the latency.
    pub compute_secs: f64,
    /// Total communication share of the latency.
    pub comm_secs: f64,
    /// Connection count (t_est-bearing messages).
    pub connections: usize,
    /// Total bytes moved.
    pub comm_bytes: u64,
    /// Eq. (1) memory report.
    pub memory: memory::MemoryReport,
}

impl PlanCost {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("total_secs", Json::num(self.total_secs)),
            ("compute_secs", Json::num(self.compute_secs)),
            ("comm_secs", Json::num(self.comm_secs)),
            ("connections", Json::num(self.connections as f64)),
            ("comm_bytes", Json::num(self.comm_bytes as f64)),
            (
                "peak_memory_bytes",
                Json::num(self.memory.peak_footprint() as f64),
            ),
            (
                "stages",
                Json::arr(
                    self.stages
                        .iter()
                        .map(|s| {
                            Json::obj(vec![
                                ("op", Json::num(s.op_idx as f64)),
                                ("comm_secs", Json::num(s.comm_secs)),
                                ("compute_secs", Json::num(s.compute_secs)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }
}

/// Evaluate a plan end-to-end: P1's objective plus the memory terms.
pub fn evaluate(model: &Model, cluster: &Cluster, plan: &Plan) -> PlanCost {
    let mut stages = Vec::with_capacity(plan.stages.len());
    let mut total_compute = 0.0;
    let mut total_comm = 0.0;
    for sp in &plan.stages {
        let comm_secs = comm::step_secs(cluster, &sp.pre_comm);
        let compute_secs = compute::stage_compute_wall(model, cluster, sp.stage, &sp.slices);
        total_comm += comm_secs;
        total_compute += compute_secs;
        stages.push(StageCost {
            op_idx: sp.stage.op_idx,
            comm_secs,
            compute_secs,
        });
    }
    let final_comm_secs = comm::step_secs(cluster, &plan.final_comm);
    total_comm += final_comm_secs;
    PlanCost {
        stages,
        final_comm_secs,
        total_secs: total_compute + total_comm,
        compute_secs: total_compute,
        comm_secs: total_comm,
        connections: plan.total_connections(),
        comm_bytes: plan.total_comm_bytes(),
        memory: memory::plan_memory(model, plan),
    }
}

/// Convenience: latency of the centralized (single-device) baseline —
/// Fig. 1(a): the whole model on the fastest device, no communication.
pub fn centralized_secs(model: &Model, cluster: &Cluster) -> f64 {
    let f = cluster
        .devices
        .iter()
        .map(|d| d.flops_per_sec)
        .fold(0.0, f64::max);
    model.total_flops() / f
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::profiles;
    use crate::model::zoo;
    use crate::partition::{coedge::plan_coedge, oc::plan_oc};

    #[test]
    fn totals_are_consistent() {
        let model = zoo::alexnet();
        let cluster = profiles::paper_default();
        let plan = plan_oc(&model, &cluster);
        let c = evaluate(&model, &cluster, &plan);
        let sum: f64 = c
            .stages
            .iter()
            .map(|s| s.comm_secs + s.compute_secs)
            .sum::<f64>()
            + c.final_comm_secs;
        assert!((sum - c.total_secs).abs() < 1e-12);
        assert!((c.compute_secs + c.comm_secs - c.total_secs).abs() < 1e-12);
        assert!(c.total_secs > 0.0);
    }

    #[test]
    fn parallel_compute_beats_centralized() {
        // With zero comm cost, 3-way OC partitioning should approach 1/3 of
        // the centralized compute time.
        let model = zoo::vgg11();
        let mut cluster = profiles::paper_default();
        cluster.t_est = 0.0;
        cluster.bandwidth_bps = 1e15; // effectively free comm
        let plan = plan_oc(&model, &cluster);
        let c = evaluate(&model, &cluster, &plan);
        let central = centralized_secs(&model, &cluster);
        assert!(c.total_secs < central * 0.45, "{} vs {central}", c.total_secs);
        assert!(c.total_secs > central / 3.0 * 0.95);
    }

    #[test]
    fn coedge_fc_phase_serializes() {
        // CoEdge compute time >= FC flops on one device.
        let model = zoo::alexnet();
        let cluster = profiles::paper_default();
        let plan = plan_coedge(&model, &cluster);
        let c = evaluate(&model, &cluster, &plan);
        let fc_flops: f64 = model
            .stages()
            .iter()
            .filter(|s| model.ops[s.op_idx].kind_tag() == "fc")
            .map(|s| model.stage_flops(*s))
            .sum();
        assert!(c.compute_secs >= fc_flops / cluster.devices[0].flops_per_sec);
    }
}
