//! Memory accounting — paper eq. (1):
//! `Σ_i ω_{i,j} + max_i a_{i,j} ≤ r_j`.
//!
//! Weights for every stage stay resident on the devices that need them
//! (`Σ ω`); activations are transient, so only the largest per-stage
//! working set counts (`max a`). The per-slice rules encode exactly why
//! Fig. 5 comes out the way it does:
//!  * OC/IC shards hold only their fraction of the weights — but an IC
//!    shard must buffer a *full-size partial sum* output;
//!  * row shards (CoEdge) replicate the *entire* conv weight tensor;
//!  * a `Full` FC stage parks every FC weight on the root.

use crate::model::{Model, OpKind, Stage};
use crate::partition::plan::{Plan, SliceKind};
use crate::partition::rows::input_rows_needed_clamped;

/// Resident weight bytes a slice of `stage` requires.
pub fn slice_weight_bytes(model: &Model, stage: Stage, slice: &SliceKind) -> u64 {
    let op = &model.ops[stage.op_idx];
    let total = op.weight_bytes();
    match (slice, &op.kind) {
        (SliceKind::Idle, _) => 0,
        (SliceKind::Full, _) | (SliceKind::Replicate, _) => total,
        // Row shards need every output channel for their rows: the whole
        // kernel tensor is replicated.
        (SliceKind::Rows { count, .. }, _) => {
            if *count == 0 {
                0
            } else {
                total
            }
        }
        (SliceKind::Oc { count, .. }, OpKind::Conv2d { c_in, k_h, k_w, .. }) => {
            4 * (*count * c_in * k_h * k_w + *count) as u64
        }
        (SliceKind::Oc { count, .. }, OpKind::Dense { c_in, .. }) => {
            4 * (*count * c_in + *count) as u64
        }
        (SliceKind::Ic { count, .. }, OpKind::Conv2d { c_out, k_h, k_w, .. }) => {
            // weight columns for `count` input channels + a replicated
            // bias (applied after the partial-sum reduction)
            4 * (c_out * count * k_h * k_w + c_out) as u64
        }
        (SliceKind::Ic { count, .. }, OpKind::Dense { c_out, .. }) => {
            4 * (c_out * count + c_out) as u64
        }
        _ => unreachable!("slice kind incompatible with op kind"),
    }
}

/// Peak activation working set of device `j` at `stage`: bytes of the input
/// it must hold plus bytes of the output it produces.
pub fn slice_activation_bytes(model: &Model, stage: Stage, slice: &SliceKind) -> u64 {
    let in_bytes = model.in_shape(stage.op_idx).bytes();
    let out_post_tail = model.stage_out_shape(stage).bytes();
    // IC shards buffer the *raw* (pre-tail) op output as a full partial sum.
    let raw_out = model.out_shape(stage.op_idx).bytes();
    let op = &model.ops[stage.op_idx];
    match slice {
        SliceKind::Idle => 0,
        SliceKind::Full | SliceKind::Replicate => in_bytes + out_post_tail,
        SliceKind::Oc { count, .. } => {
            // full input (replicated), fractional output
            let c_out = op.c_out().unwrap() as u64;
            in_bytes + out_post_tail * *count as u64 / c_out
        }
        SliceKind::Ic { count, .. } => {
            // fractional input channels, full-size partial output
            let c_in = op.c_in().unwrap() as u64;
            in_bytes * *count as u64 / c_in + raw_out
        }
        SliceKind::Rows { start, count } => {
            if *count == 0 {
                return 0;
            }
            // input rows incl. receptive-field overlap + output rows
            let spatial_out = model.stage_spatial_out_shape(stage);
            let in_shape = model.in_shape(stage.op_idx);
            let (lo, hi) = input_rows_needed_clamped(model, stage, *start, *start + *count);
            let in_rows = (hi - lo) as u64;
            let in_row_bytes = (in_shape.c * in_shape.w * 4) as u64;
            let out_row_bytes = (spatial_out.c * spatial_out.w * 4) as u64;
            in_rows * in_row_bytes + *count as u64 * out_row_bytes
        }
    }
}

/// Per-device memory report for a plan.
#[derive(Debug, Clone)]
pub struct MemoryReport {
    /// Σ_i ω_{i,j}: resident weights per device.
    pub weights: Vec<u64>,
    /// max_i a_{i,j}: peak activation working set per device.
    pub peak_activation: Vec<u64>,
}

impl MemoryReport {
    /// Eq. (1) left-hand side per device.
    pub fn footprint(&self) -> Vec<u64> {
        self.weights
            .iter()
            .zip(&self.peak_activation)
            .map(|(w, a)| w + a)
            .collect()
    }

    /// Peak footprint across devices — the Fig. 5 metric.
    pub fn peak_footprint(&self) -> u64 {
        self.footprint().into_iter().max().unwrap_or(0)
    }
}

/// Evaluate eq. (1) terms for every device.
pub fn plan_memory(model: &Model, plan: &Plan) -> MemoryReport {
    let m = plan.m;
    let mut weights = vec![0u64; m];
    let mut peak_act = vec![0u64; m];
    for sp in &plan.stages {
        for (j, slice) in sp.slices.iter().enumerate() {
            weights[j] += slice_weight_bytes(model, sp.stage, slice);
            peak_act[j] = peak_act[j].max(slice_activation_bytes(model, sp.stage, slice));
        }
    }
    MemoryReport {
        weights,
        peak_activation: peak_act,
    }
}

/// Check eq. (1) feasibility against device capacities.
pub fn check_feasible(
    model: &Model,
    plan: &Plan,
    cluster: &crate::device::Cluster,
) -> Result<(), String> {
    let rep = plan_memory(model, plan);
    for (j, fp) in rep.footprint().iter().enumerate() {
        let cap = cluster.devices[j].mem_bytes;
        if *fp > cap {
            return Err(format!(
                "device {j}: footprint {fp} exceeds capacity {cap} (eq. 1)"
            ));
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::profiles;
    use crate::model::zoo;
    use crate::partition::{coedge::plan_coedge, oc::plan_oc};

    #[test]
    fn oc_weight_slices_tile_total() {
        let model = zoo::lenet();
        let st = model.stages()[0];
        let total = model.ops[st.op_idx].weight_bytes();
        let parts: u64 = [(0usize, 2usize), (2, 2), (4, 2)]
            .iter()
            .map(|&(start, count)| {
                slice_weight_bytes(&model, st, &SliceKind::Oc { start, count })
            })
            .sum();
        assert_eq!(parts, total);
    }

    #[test]
    fn coedge_replicates_conv_weights() {
        let model = zoo::vgg11();
        let plan = plan_coedge(&model, &profiles::paper_default());
        let rep = plan_memory(&model, &plan);
        let conv_bytes: u64 = model
            .ops
            .iter()
            .filter(|o| o.kind_tag() == "conv")
            .map(|o| o.weight_bytes())
            .sum();
        // every participating device carries all conv weights
        for j in 0..plan.m {
            assert!(rep.weights[j] >= conv_bytes, "device {j}");
        }
        // the root additionally carries all FC weights
        let fc_bytes: u64 = model
            .ops
            .iter()
            .filter(|o| o.kind_tag() == "fc")
            .map(|o| o.weight_bytes())
            .sum();
        assert!(rep.weights[0] >= conv_bytes + fc_bytes);
    }

    #[test]
    fn oc_memory_well_below_coedge_on_fc_heavy_models() {
        // The Fig. 5 direction: partitioning FC layers slashes peak memory.
        let model = zoo::alexnet();
        let cluster = profiles::paper_default();
        let oc = plan_memory(&model, &plan_oc(&model, &cluster));
        let co = plan_memory(&model, &plan_coedge(&model, &cluster));
        assert!(
            oc.peak_footprint() < co.peak_footprint(),
            "oc={} coedge={}",
            oc.peak_footprint(),
            co.peak_footprint()
        );
    }

    #[test]
    fn feasibility_detects_tiny_devices() {
        let model = zoo::vgg16();
        let cluster = profiles::tiny_memory(3, 1 << 20); // 1 MiB devices
        let plan = plan_oc(&model, &cluster);
        assert!(check_feasible(&model, &plan, &cluster).is_err());
        let big = profiles::paper_default();
        let plan = plan_oc(&model, &big);
        check_feasible(&model, &plan, &big).unwrap();
    }
}
