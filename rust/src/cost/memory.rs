//! Memory accounting — paper eq. (1):
//! `Σ_i ω_{i,j} + max_i a_{i,j} ≤ r_j`.
//!
//! Weights for every stage stay resident on the devices that need them
//! (`Σ ω`); activations are transient, so only the largest per-stage
//! working set counts (`max a`). The per-slice rules encode exactly why
//! Fig. 5 comes out the way it does:
//!  * OC/IC shards hold only their fraction of the weights — but an IC
//!    shard must buffer a *full-size partial sum* output;
//!  * row shards (CoEdge) replicate the *entire* conv weight tensor;
//!  * a `Full` FC stage parks every FC weight on the root.

use crate::exec::prepack::ConvLowering;
use crate::model::{Model, OpKind, Stage};
use crate::partition::plan::{Plan, SliceKind};
use crate::partition::rows::{input_rows_needed, input_rows_needed_clamped};
use crate::tensor::gemm::pack_scratch_bytes;
use crate::tensor::kernels;
use crate::tensor::quant::Dtype;

/// Weight-tensor geometry of a slice: `(weight elements, output
/// channels)` — each carried channel holds an f32 bias and, under the
/// int8 tier, an f32 dequantization scale. `(0, 0)` for idle slices and
/// weightless ops.
fn slice_weight_elems(model: &Model, stage: Stage, slice: &SliceKind) -> (u64, u64) {
    let op = &model.ops[stage.op_idx];
    let full = || match &op.kind {
        OpKind::Conv2d {
            c_in,
            c_out,
            k_h,
            k_w,
            ..
        } => ((c_out * c_in * k_h * k_w) as u64, *c_out as u64),
        OpKind::Dense { c_in, c_out, .. } => ((c_out * c_in) as u64, *c_out as u64),
        _ => (0, 0),
    };
    match (slice, &op.kind) {
        (SliceKind::Idle, _) => (0, 0),
        (SliceKind::Full, _) | (SliceKind::Replicate, _) => full(),
        // Row shards need every output channel for their rows: the whole
        // kernel tensor is replicated.
        (SliceKind::Rows { count, .. }, _) => {
            if *count == 0 {
                (0, 0)
            } else {
                full()
            }
        }
        (SliceKind::Oc { count, .. }, OpKind::Conv2d { c_in, k_h, k_w, .. }) => {
            ((count * c_in * k_h * k_w) as u64, *count as u64)
        }
        (SliceKind::Oc { count, .. }, OpKind::Dense { c_in, .. }) => {
            ((count * c_in) as u64, *count as u64)
        }
        // IC shards: weight columns for `count` input channels + a
        // replicated bias (applied after the partial-sum reduction).
        (SliceKind::Ic { count, .. }, OpKind::Conv2d { c_out, k_h, k_w, .. }) => {
            ((c_out * count * k_h * k_w) as u64, *c_out as u64)
        }
        (SliceKind::Ic { count, .. }, OpKind::Dense { c_out, .. }) => {
            ((c_out * count) as u64, *c_out as u64)
        }
        _ => unreachable!("slice kind incompatible with op kind"),
    }
}

/// Resident weight bytes a slice of `stage` requires (f32 tier).
pub fn slice_weight_bytes(model: &Model, stage: Stage, slice: &SliceKind) -> u64 {
    slice_weight_bytes_dtype(model, stage, slice, Dtype::F32)
}

/// Resident weight bytes under a compute dtype: f32 stores 4 bytes per
/// weight element and per bias; int8 stores one byte per weight element
/// plus 8 per output channel (f32 bias + f32 dequant scale) — the ~4x
/// panel shrink the quantized tier buys.
pub fn slice_weight_bytes_dtype(
    model: &Model,
    stage: Stage,
    slice: &SliceKind,
    dtype: Dtype,
) -> u64 {
    let (w, ch) = slice_weight_elems(model, stage, slice);
    match dtype {
        Dtype::F32 => 4 * w + 4 * ch,
        Dtype::I8 => w + 8 * ch,
    }
}

/// Peak activation working set of device `j` at `stage`: bytes of the input
/// it must hold plus bytes of the output it produces.
pub fn slice_activation_bytes(model: &Model, stage: Stage, slice: &SliceKind) -> u64 {
    let in_bytes = model.in_shape(stage.op_idx).bytes();
    let out_post_tail = model.stage_out_shape(stage).bytes();
    // IC shards buffer the *raw* (pre-tail) op output as a full partial sum.
    let raw_out = model.out_shape(stage.op_idx).bytes();
    let op = &model.ops[stage.op_idx];
    match slice {
        SliceKind::Idle => 0,
        SliceKind::Full | SliceKind::Replicate => in_bytes + out_post_tail,
        SliceKind::Oc { count, .. } => {
            // full input (replicated), fractional output
            let c_out = op.c_out().unwrap() as u64;
            in_bytes + out_post_tail * *count as u64 / c_out
        }
        SliceKind::Ic { count, .. } => {
            // fractional input channels, full-size partial output
            let c_in = op.c_in().unwrap() as u64;
            in_bytes * *count as u64 / c_in + raw_out
        }
        SliceKind::Rows { start, count } => {
            if *count == 0 {
                return 0;
            }
            // input rows incl. receptive-field overlap + output rows
            let spatial_out = model.stage_spatial_out_shape(stage);
            let in_shape = model.in_shape(stage.op_idx);
            let (lo, hi) = input_rows_needed_clamped(model, stage, *start, *start + *count);
            let in_rows = (hi - lo) as u64;
            let in_row_bytes = (in_shape.c * in_shape.w * 4) as u64;
            let out_row_bytes = (spatial_out.c * spatial_out.w * 4) as u64;
            in_rows * in_row_bytes + *count as u64 * out_row_bytes
        }
    }
}

/// The local GEMM problem `(k, n)` a conv slice lowers onto — the
/// geometry `exec::prepack::compile_slice` resolves: OC shards keep the
/// full reduction depth and output plane (only output rows of the
/// weight matrix shrink), IC shards cut the depth, row shards cut the
/// output plane (window conv, vertical padding pre-materialized).
fn conv_gemm_dims(model: &Model, stage: Stage, slice: &SliceKind) -> Option<(usize, usize)> {
    let op = &model.ops[stage.op_idx];
    let OpKind::Conv2d {
        c_in,
        k_h,
        k_w,
        stride,
        pad,
        ..
    } = op.kind
    else {
        return None;
    };
    let ish = model.in_shape(stage.op_idx);
    let out_h = (ish.h + 2 * pad - k_h) / stride + 1;
    let out_w = (ish.w + 2 * pad - k_w) / stride + 1;
    match slice {
        SliceKind::Idle => None,
        SliceKind::Full | SliceKind::Replicate | SliceKind::Oc { .. } => {
            Some((c_in * k_h * k_w, out_h * out_w))
        }
        SliceKind::Ic { count, .. } => Some((count * k_h * k_w, out_h * out_w)),
        SliceKind::Rows { start, count } => {
            if *count == 0 {
                return None;
            }
            // `count` is *stage-output* rows (post-tail-pool); the conv
            // itself runs over the materialized input-row window with
            // vertical padding pre-applied, so its GEMM columns are the
            // window's conv-output rows (e.g. 2·count under a 2×2 pool
            // tail) — mirror the runtime window exactly.
            let (lo, hi) = input_rows_needed(model, stage, *start, *start + *count);
            let win_h = (hi - lo) as usize;
            let rows_out = (win_h - k_h) / stride + 1;
            Some((c_in * k_h * k_w, rows_out * out_w))
        }
    }
}

/// Analytical transient im2col scratch a conv slice needs under a given
/// lowering (`exec::prepack::run_conv`): fused implicit GEMM touches
/// only the per-thread B-panel pack buffers
/// (`gemm::pack_scratch_bytes`, sized for the runtime-selected
/// microkernel's tile width); the materialized twin additionally holds
/// the full `k×n` column matrix. Exact for `threads = 1` (the harness
/// worker default); an upper bound otherwise (the GEMM may clamp its
/// row split below `threads` on small problems). 0 for non-conv slices.
pub fn slice_conv_scratch_bytes(
    model: &Model,
    stage: Stage,
    slice: &SliceKind,
    lowering: ConvLowering,
    threads: usize,
) -> u64 {
    let Some((k, n)) = conv_gemm_dims(model, stage, slice) else {
        return 0;
    };
    let pack =
        pack_scratch_bytes(kernels::selected(), k, n) as u64 * threads.max(1) as u64;
    match lowering {
        ConvLowering::Fused => pack,
        ConvLowering::Materialized => (k * n * 4) as u64 + pack,
    }
}

/// Per-device peak transient conv scratch of a plan under both
/// lowerings — the analytical counterpart of the measured
/// `ExecStats::peak_scratch_bytes` (the compiled workers' grow-only
/// arenas reach exactly these high-water marks at `threads = 1`).
#[derive(Debug, Clone)]
pub struct ScratchReport {
    /// Fused implicit GEMM: max pack-buffer bytes over stages.
    pub fused: Vec<u64>,
    /// Materialized im2col: the arena's `cols` buffer grows to the
    /// largest column matrix and the pack buffers to their own maximum
    /// independently, so the peak is the *sum of the two maxima* (they
    /// coexist in one grow-only arena), not the max of per-stage sums.
    pub materialized: Vec<u64>,
}

impl ScratchReport {
    /// Largest per-device fused footprint (the Fig. 5-style headline).
    pub fn peak_fused(&self) -> u64 {
        self.fused.iter().copied().max().unwrap_or(0)
    }

    pub fn peak_materialized(&self) -> u64 {
        self.materialized.iter().copied().max().unwrap_or(0)
    }
}

/// Evaluate [`ScratchReport`] for every device of a plan.
pub fn plan_conv_scratch(model: &Model, plan: &Plan, threads: usize) -> ScratchReport {
    let m = plan.m;
    let mut pack_max = vec![0u64; m];
    let mut cols_max = vec![0u64; m];
    for sp in &plan.stages {
        for (j, slice) in sp.slices.iter().enumerate() {
            let Some((k, n)) = conv_gemm_dims(model, sp.stage, slice) else {
                continue;
            };
            let pack = pack_scratch_bytes(kernels::selected(), k, n) as u64
                * threads.max(1) as u64;
            pack_max[j] = pack_max[j].max(pack);
            cols_max[j] = cols_max[j].max((k * n * 4) as u64);
        }
    }
    let materialized = cols_max
        .iter()
        .zip(&pack_max)
        .map(|(c, p)| c + p)
        .collect();
    ScratchReport {
        fused: pack_max,
        materialized,
    }
}

/// Per-device memory report for a plan.
#[derive(Debug, Clone)]
pub struct MemoryReport {
    /// Σ_i ω_{i,j}: resident weights per device.
    pub weights: Vec<u64>,
    /// max_i a_{i,j}: peak activation working set per device.
    pub peak_activation: Vec<u64>,
}

impl MemoryReport {
    /// Eq. (1) left-hand side per device.
    pub fn footprint(&self) -> Vec<u64> {
        self.weights
            .iter()
            .zip(&self.peak_activation)
            .map(|(w, a)| w + a)
            .collect()
    }

    /// Peak footprint across devices — the Fig. 5 metric.
    pub fn peak_footprint(&self) -> u64 {
        self.footprint().into_iter().max().unwrap_or(0)
    }
}

/// Evaluate eq. (1) terms for every device (f32 tier).
pub fn plan_memory(model: &Model, plan: &Plan) -> MemoryReport {
    plan_memory_dtype(model, plan, Dtype::F32)
}

/// Evaluate eq. (1) terms for every device under a compute dtype.
/// Activations are dequantized to f32 at every stage boundary in the
/// int8 tier, so only the resident-weight term shrinks.
pub fn plan_memory_dtype(model: &Model, plan: &Plan, dtype: Dtype) -> MemoryReport {
    let m = plan.m;
    let mut weights = vec![0u64; m];
    let mut peak_act = vec![0u64; m];
    for sp in &plan.stages {
        for (j, slice) in sp.slices.iter().enumerate() {
            weights[j] += slice_weight_bytes_dtype(model, sp.stage, slice, dtype);
            peak_act[j] = peak_act[j].max(slice_activation_bytes(model, sp.stage, slice));
        }
    }
    MemoryReport {
        weights,
        peak_activation: peak_act,
    }
}

/// Check eq. (1) feasibility against device capacities.
pub fn check_feasible(
    model: &Model,
    plan: &Plan,
    cluster: &crate::device::Cluster,
) -> Result<(), String> {
    let rep = plan_memory(model, plan);
    for (j, fp) in rep.footprint().iter().enumerate() {
        let cap = cluster.devices[j].mem_bytes;
        if *fp > cap {
            return Err(format!(
                "device {j}: footprint {fp} exceeds capacity {cap} (eq. 1)"
            ));
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::profiles;
    use crate::model::zoo;
    use crate::partition::{coedge::plan_coedge, oc::plan_oc};

    #[test]
    fn oc_weight_slices_tile_total() {
        let model = zoo::lenet();
        let st = model.stages()[0];
        let total = model.ops[st.op_idx].weight_bytes();
        let parts: u64 = [(0usize, 2usize), (2, 2), (4, 2)]
            .iter()
            .map(|&(start, count)| {
                slice_weight_bytes(&model, st, &SliceKind::Oc { start, count })
            })
            .sum();
        assert_eq!(parts, total);
    }

    #[test]
    fn coedge_replicates_conv_weights() {
        let model = zoo::vgg11();
        let plan = plan_coedge(&model, &profiles::paper_default());
        let rep = plan_memory(&model, &plan);
        let conv_bytes: u64 = model
            .ops
            .iter()
            .filter(|o| o.kind_tag() == "conv")
            .map(|o| o.weight_bytes())
            .sum();
        // every participating device carries all conv weights
        for j in 0..plan.m {
            assert!(rep.weights[j] >= conv_bytes, "device {j}");
        }
        // the root additionally carries all FC weights
        let fc_bytes: u64 = model
            .ops
            .iter()
            .filter(|o| o.kind_tag() == "fc")
            .map(|o| o.weight_bytes())
            .sum();
        assert!(rep.weights[0] >= conv_bytes + fc_bytes);
    }

    #[test]
    fn oc_memory_well_below_coedge_on_fc_heavy_models() {
        // The Fig. 5 direction: partitioning FC layers slashes peak memory.
        let model = zoo::alexnet();
        let cluster = profiles::paper_default();
        let oc = plan_memory(&model, &plan_oc(&model, &cluster));
        let co = plan_memory(&model, &plan_coedge(&model, &cluster));
        assert!(
            oc.peak_footprint() < co.peak_footprint(),
            "oc={} coedge={}",
            oc.peak_footprint(),
            co.peak_footprint()
        );
    }

    #[test]
    fn fused_scratch_model_beats_materialized_on_every_device() {
        use crate::partition::Strategy;
        let model = zoo::vgg_mini();
        let cluster = profiles::paper_default();
        for strategy in Strategy::all() {
            let plan = crate::pipeline::plan(&model, &cluster, strategy);
            let rep = plan_conv_scratch(&model, &plan, 1);
            for j in 0..plan.m {
                if rep.materialized[j] == 0 {
                    assert_eq!(rep.fused[j], 0, "{} dev {j}", strategy.name());
                    continue;
                }
                // Every conv-carrying device saves at least the column
                // matrix (the pack buffers are common to both paths).
                assert!(
                    rep.fused[j] < rep.materialized[j],
                    "{} dev {j}: fused {} vs materialized {}",
                    strategy.name(),
                    rep.fused[j],
                    rep.materialized[j]
                );
            }
            assert!(rep.peak_fused() > 0, "{}", strategy.name());
            // The acceptance direction on the bottleneck device: fused
            // transient scratch ≥ 25% below the materialized arena's.
            assert!(
                rep.peak_fused() * 4 <= rep.peak_materialized() * 3,
                "{}: peak fused {} vs materialized {}",
                strategy.name(),
                rep.peak_fused(),
                rep.peak_materialized()
            );
        }
    }

    #[test]
    fn slice_scratch_covers_every_slice_kind() {
        let model = zoo::vgg_mini();
        let st = model.stages()[0]; // conv1: 3->8 ch, 32x32, pad 1
        let full_mat = slice_conv_scratch_bytes(
            &model,
            st,
            &SliceKind::Full,
            ConvLowering::Materialized,
            1,
        );
        let full_fused =
            slice_conv_scratch_bytes(&model, st, &SliceKind::Full, ConvLowering::Fused, 1);
        // materialized = cols + pack; cols for conv1 is 27*1024*4 bytes.
        assert_eq!(full_mat, full_fused + 27 * 1024 * 4);
        // Row shards shrink n proportionally to their row count.
        let rows = slice_conv_scratch_bytes(
            &model,
            st,
            &SliceKind::Rows { start: 0, count: 8 },
            ConvLowering::Materialized,
            1,
        );
        assert!(rows < full_mat);
        // IC shards shrink k.
        let ic = slice_conv_scratch_bytes(
            &model,
            model.stages()[1],
            &SliceKind::Ic { start: 0, count: 2 },
            ConvLowering::Materialized,
            1,
        );
        let ic_full = slice_conv_scratch_bytes(
            &model,
            model.stages()[1],
            &SliceKind::Full,
            ConvLowering::Materialized,
            1,
        );
        assert!(ic < ic_full);
        // Idle and dense slices need no conv scratch.
        assert_eq!(
            slice_conv_scratch_bytes(&model, st, &SliceKind::Idle, ConvLowering::Fused, 1),
            0
        );
        let fc = *model.stages().last().unwrap();
        assert_eq!(
            slice_conv_scratch_bytes(&model, fc, &SliceKind::Full, ConvLowering::Materialized, 1),
            0
        );
    }

    #[test]
    fn int8_weight_bytes_shrink_near_4x() {
        let model = zoo::lenet();
        for st in model.stages() {
            for slice in [
                SliceKind::Full,
                SliceKind::Oc { start: 0, count: 2 },
                SliceKind::Ic { start: 0, count: 1 },
            ] {
                // Oc/Ic shards only apply to weighted ops.
                if matches!(slice, SliceKind::Oc { .. } | SliceKind::Ic { .. })
                    && model.ops[st.op_idx].c_out().is_none()
                {
                    continue;
                }
                let f32b = slice_weight_bytes_dtype(&model, st, &slice, Dtype::F32);
                let i8b = slice_weight_bytes_dtype(&model, st, &slice, Dtype::I8);
                assert_eq!(f32b, slice_weight_bytes(&model, st, &slice));
                if f32b == 0 {
                    assert_eq!(i8b, 0);
                    continue;
                }
                assert!(i8b < f32b, "{slice:?}: i8 {i8b} vs f32 {f32b}");
            }
        }
        // Whole-plan resident weights: the per-channel scale/bias
        // overhead is tiny next to the 4x element shrink.
        let cluster = profiles::paper_default();
        let plan = plan_oc(&model, &cluster);
        let f32_total: u64 = plan_memory_dtype(&model, &plan, Dtype::F32).weights.iter().sum();
        let i8_total: u64 = plan_memory_dtype(&model, &plan, Dtype::I8).weights.iter().sum();
        assert!(
            (f32_total as f64) / (i8_total as f64) >= 3.5,
            "resident-weight shrink {f32_total}/{i8_total} below 3.5x"
        );
    }

    #[test]
    fn feasibility_detects_tiny_devices() {
        let model = zoo::vgg16();
        let cluster = profiles::tiny_memory(3, 1 << 20); // 1 MiB devices
        let plan = plan_oc(&model, &cluster);
        assert!(check_feasible(&model, &plan, &cluster).is_err());
        let big = profiles::paper_default();
        let plan = plan_oc(&model, &big);
        check_feasible(&model, &plan, &big).unwrap();
    }
}
