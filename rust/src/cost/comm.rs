//! Communication pricing — paper eq. (8) plus the connection-establishment
//! term the Fig. 6 sweep varies.
//!
//! Medium model (DESIGN.md §2/§4): the cluster shares a wireless medium, so
//! unicast messages serialize; each message costs `t_est + bytes / b`.
//! This is the model under which the paper's connection-count argument
//! (IOP's `2(m-1)` vs OC's `m(m-1)` per layer-pair) turns into latency.

use crate::device::Cluster;
use crate::partition::plan::CommStep;
use crate::tensor::quant::WireDtype;

/// Scale f32-denominated payload bytes to their on-wire size: plans
/// size every [`CommStep`] in f32 elements (4 bytes each); an f16 wire
/// carries the same elements at 2 bytes. Message *count* — and with it
/// the `t_est` establishment term — is unchanged.
fn on_wire_bytes(bytes: u64, wire: WireDtype) -> u64 {
    match wire {
        WireDtype::F32 => bytes,
        WireDtype::F16 => bytes / 2,
    }
}

/// Seconds for one unicast message (f32 wire).
pub fn message_secs(cluster: &Cluster, bytes: u64) -> f64 {
    message_secs_wire(cluster, bytes, WireDtype::F32)
}

/// Seconds for one unicast message under a wire dtype.
pub fn message_secs_wire(cluster: &Cluster, bytes: u64, wire: WireDtype) -> f64 {
    cluster.t_est + cluster.xfer_secs(on_wire_bytes(bytes, wire))
}

/// Seconds for a whole communication step (serialized shared medium,
/// f32 wire).
pub fn step_secs(cluster: &Cluster, step: &CommStep) -> f64 {
    step_secs_wire(cluster, step, WireDtype::F32)
}

/// Seconds for a whole communication step under a wire dtype.
pub fn step_secs_wire(cluster: &Cluster, step: &CommStep, wire: WireDtype) -> f64 {
    step.messages(cluster.m())
        .iter()
        .map(|&(_, _, b)| message_secs_wire(cluster, b, wire))
        .sum()
}

/// Decompose a step into (establishment seconds, transfer seconds).
pub fn step_breakdown(cluster: &Cluster, step: &CommStep) -> (f64, f64) {
    step_breakdown_wire(cluster, step, WireDtype::F32)
}

/// [`step_breakdown`] under a wire dtype: f16 halves the transfer term
/// and leaves establishment alone, so the connection-count argument the
/// paper makes is unchanged by payload compression.
pub fn step_breakdown_wire(cluster: &Cluster, step: &CommStep, wire: WireDtype) -> (f64, f64) {
    let msgs = step.messages(cluster.m());
    let est = msgs.len() as f64 * cluster.t_est;
    let xfer: f64 = msgs
        .iter()
        .map(|&(_, _, b)| cluster.xfer_secs(on_wire_bytes(b, wire)))
        .sum();
    (est, xfer)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::Cluster;

    fn cluster(t_est: f64) -> Cluster {
        Cluster::homogeneous(3, 1e9, 1 << 30, 12.5e6, t_est)
    }

    #[test]
    fn allgather_vs_reduce_broadcast_scaling() {
        // The paper's core latency argument, in closed form for m=3 and
        // equal per-layer activation size `a`:
        //   OC over a layer pair:  2 AllGathers = 12 t_est + 4a/b
        //   IOP over the pair:     1 ReduceBcast = 4 t_est + 4a/b
        //   saving = 8 t_est — grows linearly in t_est (Fig. 6's trend).
        let a = 120_000u64; // divisible by m so AG slices tile exactly
        let m = 3usize;
        let ag = CommStep::AllGather {
            bytes_per_dev: vec![a / m as u64; m],
        };
        let rb = CommStep::ReduceBroadcast { root: 0, bytes: a };
        for t in [0.001, 0.004, 0.008] {
            let c = cluster(t);
            let two_ag = 2.0 * step_secs(&c, &ag);
            let one_rb = step_secs(&c, &rb);
            assert_eq!(ag.connections(m) * 2, 12);
            assert_eq!(rb.connections(m), 4);
            let saving = two_ag - one_rb;
            assert!((saving - 8.0 * t).abs() < 1e-9, "t={t}, saving={saving}");
        }
    }

    #[test]
    fn step_secs_counts_every_message() {
        let c = cluster(0.002);
        let g = CommStep::Gather {
            root: 0,
            bytes_per_dev: vec![0, 12_500, 25_000],
        };
        // two messages: 12.5 KB and 25 KB
        let expect = 2.0 * 0.002 + (12_500.0 + 25_000.0) / 12.5e6;
        assert!((step_secs(&c, &g) - expect).abs() < 1e-12);
    }

    #[test]
    fn breakdown_sums_to_total() {
        let c = cluster(0.003);
        let step = CommStep::ReduceBroadcast {
            root: 1,
            bytes: 99_000,
        };
        let (est, xfer) = step_breakdown(&c, &step);
        assert!((est + xfer - step_secs(&c, &step)).abs() < 1e-12);
        assert!((est - 4.0 * 0.003).abs() < 1e-12);
    }

    #[test]
    fn none_is_free() {
        let c = cluster(0.008);
        assert_eq!(step_secs(&c, &CommStep::None), 0.0);
    }

    #[test]
    fn f16_wire_halves_transfer_not_establishment() {
        let c = cluster(0.004);
        let step = CommStep::ReduceBroadcast {
            root: 0,
            bytes: 80_000,
        };
        let (est32, xfer32) = step_breakdown_wire(&c, &step, WireDtype::F32);
        let (est16, xfer16) = step_breakdown_wire(&c, &step, WireDtype::F16);
        assert_eq!(est32, est16, "t_est term is per message, not per byte");
        assert!((xfer16 - xfer32 / 2.0).abs() < 1e-12);
        assert!(
            (step_secs_wire(&c, &step, WireDtype::F16) - (est16 + xfer16)).abs() < 1e-12
        );
        // f32 wrappers stay exactly the old pricing.
        assert_eq!(step_secs(&c, &step), step_secs_wire(&c, &step, WireDtype::F32));
    }
}
