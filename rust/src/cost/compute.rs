//! Compute-time pricing — paper eq. (7): `T^c_{i,j} = c_{i,j} / f_j`.
//!
//! The per-device workload `c_{i,j}` follows from the stage's slice kind:
//!  * `Oc{count}`   — `count / c_out` of the stage (weighted op + tail);
//!  * `Ic{count}`   — `count / c_in` of the weighted op's linear part, plus
//!                    the *full* tail: an IC shard yields partial sums that
//!                    are reduced before the (nonlinear) tail can run, and
//!                    the tail is then evaluated replicated on each device
//!                    (bias + ReLU + pool are negligible next to the conv);
//!  * `Rows{count}` — `count / H` of the stage;
//!  * `Full`        — the entire stage on that device;
//!  * `Idle`        — nothing.

use crate::device::Cluster;
use crate::model::{Model, Stage};
use crate::partition::plan::SliceKind;

/// FLOPs device `j` performs for `stage` under `slice`.
///
/// For `Rows` slices this is the *stage-granular* view (the executor's
/// work assignment); the cost model refines the head-op share via
/// [`stage_device_flops`] — see below.
pub fn slice_flops(model: &Model, stage: Stage, slice: &SliceKind) -> f64 {
    let op = &model.ops[stage.op_idx];
    let head_flops = model.flops(stage.op_idx);
    let tail_flops: f64 = (stage.op_idx + 1..stage.tail_end)
        .map(|i| model.flops(i))
        .sum();
    match slice {
        SliceKind::Full | SliceKind::Replicate => head_flops + tail_flops,
        SliceKind::Idle => 0.0,
        SliceKind::Oc { count, .. } => {
            let c_out = op.c_out().expect("weighted") as f64;
            (head_flops + tail_flops) * *count as f64 / c_out
        }
        SliceKind::Ic { count, .. } => {
            let c_in = op.c_in().expect("weighted") as f64;
            head_flops * *count as f64 / c_in + tail_flops
        }
        SliceKind::Rows { count, .. } => {
            let h = model.stage_spatial_out_shape(stage).h as f64;
            (head_flops + tail_flops) * *count as f64 / h
        }
    }
}

/// FLOPs device `j` performs for `stage`, with CoEdge-faithful row
/// accounting: CoEdge partitions *every operator* on its own row
/// dimension, so the expensive head conv is balanced over its own (finer)
/// output rows even when the stage's post-pool row count quantizes
/// coarsely (e.g. AlexNet's 27-row convs feeding 13-row pools). The
/// cheap pool tail keeps the stage-granular share.
pub fn stage_device_flops(
    model: &Model,
    cluster: &Cluster,
    stage: Stage,
    slices: &[SliceKind],
    j: usize,
) -> f64 {
    match &slices[j] {
        SliceKind::Rows { count, .. } => {
            let head_flops = model.flops(stage.op_idx);
            let tail_flops: f64 = (stage.op_idx + 1..stage.tail_end)
                .map(|i| model.flops(i))
                .sum();
            // Head conv balanced over its own output rows.
            let h_head = model.out_shape(stage.op_idx).h;
            let head_counts =
                crate::partition::split::proportional_split(h_head, &cluster.compute_shares());
            let h_tail = model.stage_spatial_out_shape(stage).h as f64;
            head_flops * head_counts[j] as f64 / h_head as f64
                + tail_flops * *count as f64 / h_tail
        }
        s => slice_flops(model, stage, s),
    }
}

/// Per-device compute seconds for one stage.
pub fn stage_compute_secs(
    model: &Model,
    cluster: &Cluster,
    stage: Stage,
    slices: &[SliceKind],
) -> Vec<f64> {
    (0..slices.len())
        .map(|j| {
            stage_device_flops(model, cluster, stage, slices, j)
                / cluster.devices[j].flops_per_sec
        })
        .collect()
}

/// The stage's wall-clock compute phase: `max_j T^c_{i,j}` (eq. 6's inner
/// max — devices compute in parallel, the stage ends when the slowest
/// finishes).
pub fn stage_compute_wall(
    model: &Model,
    cluster: &Cluster,
    stage: Stage,
    slices: &[SliceKind],
) -> f64 {
    stage_compute_secs(model, cluster, stage, slices)
        .into_iter()
        .fold(0.0, f64::max)
}

/// Per-device compute seconds for one stage serving a cross-request
/// batch of `batch` members. The batch axis multiplies the workload
/// linearly: every member runs the identical slice, and the batched
/// GEMM concatenates member columns without changing per-element FLOPs
/// — so the model is `batch × stage_compute_secs`. (The *throughput*
/// win from batching is not modeled here: it comes from tile occupancy
/// and amortized weight-pack reuse, which the FLOP count is blind to.
/// The serve harness measures it instead.)
pub fn stage_compute_secs_batched(
    model: &Model,
    cluster: &Cluster,
    stage: Stage,
    slices: &[SliceKind],
    batch: usize,
) -> Vec<f64> {
    let b = batch.max(1) as f64;
    stage_compute_secs(model, cluster, stage, slices)
        .into_iter()
        .map(|s| s * b)
        .collect()
}

/// Wall-clock compute phase for a batched stage: `max_j` of
/// [`stage_compute_secs_batched`].
pub fn stage_compute_wall_batched(
    model: &Model,
    cluster: &Cluster,
    stage: Stage,
    slices: &[SliceKind],
    batch: usize,
) -> f64 {
    stage_compute_secs_batched(model, cluster, stage, slices, batch)
        .into_iter()
        .fold(0.0, f64::max)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::profiles;
    use crate::model::zoo;

    #[test]
    fn fractions_sum_to_full_for_oc() {
        let m = zoo::lenet();
        let st = m.stages()[0];
        let full = slice_flops(&m, st, &SliceKind::Full);
        let parts = [
            SliceKind::Oc { start: 0, count: 2 },
            SliceKind::Oc { start: 2, count: 3 },
            SliceKind::Oc { start: 5, count: 1 },
        ];
        let sum: f64 = parts.iter().map(|s| slice_flops(&m, st, s)).sum();
        assert!((sum - full).abs() / full < 1e-12);
    }

    #[test]
    fn ic_pays_full_tail() {
        let m = zoo::lenet();
        let st = m.stages()[1]; // conv2 + pool2 + flatten
        let head = m.flops(st.op_idx);
        let tail: f64 = (st.op_idx + 1..st.tail_end).map(|i| m.flops(i)).sum();
        let f = slice_flops(&m, st, &SliceKind::Ic { start: 0, count: 3 });
        assert!((f - (head * 3.0 / 6.0 + tail)).abs() < 1e-9);
    }

    #[test]
    fn wall_is_max_over_devices() {
        let m = zoo::lenet();
        let c = profiles::heterogeneous();
        let st = m.stages()[0];
        let slices = vec![
            SliceKind::Oc { start: 0, count: 2 },
            SliceKind::Oc { start: 2, count: 2 },
            SliceKind::Oc { start: 4, count: 2 },
        ];
        let per = stage_compute_secs(&m, &c, st, &slices);
        // equal work, slowest device defines the wall
        assert!((stage_compute_wall(&m, &c, st, &slices) - per[2]).abs() < 1e-15);
        assert!(per[2] > per[0]);
    }

    #[test]
    fn batched_cost_scales_linearly_and_normalizes_zero() {
        let m = zoo::lenet();
        let c = profiles::heterogeneous();
        let st = m.stages()[0];
        let slices = vec![
            SliceKind::Oc { start: 0, count: 2 },
            SliceKind::Oc { start: 2, count: 2 },
            SliceKind::Oc { start: 4, count: 2 },
        ];
        let one = stage_compute_secs(&m, &c, st, &slices);
        let four = stage_compute_secs_batched(&m, &c, st, &slices, 4);
        for (a, b) in one.iter().zip(&four) {
            assert!((b - 4.0 * a).abs() < 1e-15);
        }
        let wall = stage_compute_wall(&m, &c, st, &slices);
        assert!((stage_compute_wall_batched(&m, &c, st, &slices, 4) - 4.0 * wall).abs() < 1e-15);
        // batch 0 is normalized to 1 (a dispatched batch has ≥ 1 member)
        assert_eq!(
            stage_compute_secs_batched(&m, &c, st, &slices, 0),
            stage_compute_secs_batched(&m, &c, st, &slices, 1)
        );
    }

    #[test]
    fn idle_costs_nothing() {
        let m = zoo::lenet();
        assert_eq!(slice_flops(&m, m.stages()[0], &SliceKind::Idle), 0.0);
    }
}
