//! In-house micro-benchmark harness (the offline build has no criterion).
//!
//! Measures a closure with warmup, fixed-duration sampling, and robust
//! statistics (median + MAD, outlier-trimmed mean). `cargo bench` targets
//! use [`Bencher`] for hot-path measurements and plain table printing for
//! the paper-figure regenerations (which are analytic, not timing-bound).

use crate::util::json::Json;
use std::time::{Duration, Instant};

/// Robust summary of a sample of per-iteration times (seconds).
#[derive(Debug, Clone)]
pub struct Stats {
    pub samples: usize,
    pub median: f64,
    pub mean_trimmed: f64,
    pub min: f64,
    pub max: f64,
    /// Median absolute deviation (scaled): robust spread estimate.
    pub mad: f64,
}

impl Stats {
    pub fn from_samples(mut xs: Vec<f64>) -> Stats {
        assert!(!xs.is_empty());
        xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = xs.len();
        let median = xs[n / 2];
        let mut devs: Vec<f64> = xs.iter().map(|x| (x - median).abs()).collect();
        devs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let mad = devs[n / 2] * 1.4826;
        // trim 10% each side
        let lo = n / 10;
        let hi = n - lo;
        let trimmed = &xs[lo..hi];
        let mean_trimmed = trimmed.iter().sum::<f64>() / trimmed.len() as f64;
        Stats {
            samples: n,
            median,
            mean_trimmed,
            min: xs[0],
            max: xs[n - 1],
            mad,
        }
    }

    pub fn per_sec(&self) -> f64 {
        if self.median > 0.0 {
            1.0 / self.median
        } else {
            f64::INFINITY
        }
    }

    /// Machine-readable summary (BENCH_*.json case body).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("samples", Json::num(self.samples as f64)),
            ("median_secs", Json::num(self.median)),
            ("mad_secs", Json::num(self.mad)),
            ("mean_trimmed_secs", Json::num(self.mean_trimmed)),
            ("min_secs", Json::num(self.min)),
            ("max_secs", Json::num(self.max)),
        ])
    }
}

/// Accumulates named measurements and serializes them to a BENCH_*.json
/// report (median + MAD per case) so CI runs leave a perf trajectory
/// future PRs can diff against.
#[derive(Debug, Default)]
pub struct BenchReport {
    cases: Vec<(String, Stats)>,
}

impl BenchReport {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn add(&mut self, name: &str, stats: Stats) {
        self.cases.push((name.to_string(), stats));
    }

    pub fn get(&self, name: &str) -> Option<&Stats> {
        self.cases
            .iter()
            .find(|(n, _)| n == name)
            .map(|(_, s)| s)
    }

    pub fn to_json(&self) -> Json {
        let cases = self
            .cases
            .iter()
            .map(|(name, stats)| {
                let mut obj = match stats.to_json() {
                    Json::Obj(map) => map,
                    _ => unreachable!("Stats::to_json returns an object"),
                };
                obj.insert("name".to_string(), Json::str(name.clone()));
                Json::Obj(obj)
            })
            .collect();
        Json::obj(vec![
            (
                "generated_by",
                Json::str("cargo bench --bench perf_hotpath"),
            ),
            // The auto-selected microkernel on the machine that produced
            // these numbers (individual cases may force a variant — the
            // case name says so, e.g. "(..., scalar kernel)").
            (
                "kernel_isa",
                Json::str(crate::tensor::kernels::selected().describe()),
            ),
            // Its int8-tier counterpart (the "(..., i8)" cases run on it).
            (
                "kernel_isa_i8",
                Json::str(crate::tensor::kernels::selected_i8().describe()),
            ),
            ("cases", Json::Arr(cases)),
        ])
    }

    pub fn write(&self, path: &str) -> std::io::Result<()> {
        std::fs::write(path, self.to_json().to_string_pretty())
    }
}

/// Benchmark runner.
pub struct Bencher {
    pub warmup: Duration,
    pub measure: Duration,
    pub max_samples: usize,
}

impl Default for Bencher {
    fn default() -> Self {
        Self {
            warmup: Duration::from_millis(200),
            measure: Duration::from_millis(800),
            max_samples: 10_000,
        }
    }
}

impl Bencher {
    /// Fast settings for CI/tests.
    pub fn quick() -> Self {
        Self {
            warmup: Duration::from_millis(20),
            measure: Duration::from_millis(100),
            max_samples: 2_000,
        }
    }

    /// Measure `f`, preventing the result from being optimized away via
    /// the returned value sink.
    pub fn run<T>(&self, mut f: impl FnMut() -> T) -> Stats {
        // Warmup.
        let t0 = Instant::now();
        while t0.elapsed() < self.warmup {
            std::hint::black_box(f());
        }
        // Measure.
        let mut samples = Vec::new();
        let t1 = Instant::now();
        while t1.elapsed() < self.measure && samples.len() < self.max_samples {
            let s = Instant::now();
            std::hint::black_box(f());
            samples.push(s.elapsed().as_secs_f64());
        }
        Stats::from_samples(samples)
    }

    /// Measure and print one line in a uniform format.
    pub fn report<T>(&self, name: &str, f: impl FnMut() -> T) -> Stats {
        let st = self.run(f);
        println!(
            "bench {name:<44} median {:>12} ({:>10}/s)  n={}",
            crate::util::units::fmt_secs(st.median),
            format!("{:.1}", st.per_sec()),
            st.samples
        );
        st
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_of_constant_sample() {
        let s = Stats::from_samples(vec![2.0; 50]);
        assert_eq!(s.median, 2.0);
        assert_eq!(s.mad, 0.0);
        assert_eq!(s.min, 2.0);
        assert_eq!(s.max, 2.0);
    }

    #[test]
    fn stats_robust_to_outliers() {
        let mut xs = vec![1.0; 99];
        xs.push(1000.0);
        let s = Stats::from_samples(xs);
        assert_eq!(s.median, 1.0);
        assert!(s.mean_trimmed < 1.5);
    }

    #[test]
    fn report_serializes_cases() {
        let mut rep = BenchReport::new();
        rep.add("case a", Stats::from_samples(vec![1.0, 2.0, 3.0]));
        assert!(rep.get("case a").is_some());
        assert!(rep.get("case b").is_none());
        let text = rep.to_json().to_string_pretty();
        let parsed = Json::parse(&text).unwrap();
        let cases = parsed.get("cases").as_arr().unwrap();
        assert_eq!(cases.len(), 1);
        assert_eq!(cases[0].get("name").as_str(), Some("case a"));
        assert_eq!(cases[0].get("median_secs").as_f64(), Some(2.0));
    }

    #[test]
    fn bencher_measures_something() {
        let b = Bencher::quick();
        let st = b.run(|| {
            let mut x = 0u64;
            for i in 0..100 {
                x = x.wrapping_add(i);
            }
            x
        });
        assert!(st.samples > 10);
        assert!(st.median > 0.0);
    }
}
