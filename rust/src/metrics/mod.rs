//! Reporting: turn plans/costs/sim results into the tables the paper's
//! figures plot, in both human (ASCII table) and machine (JSON) form.

use crate::cost::PlanCost;
use crate::device::Cluster;
use crate::model::Model;
use crate::partition::{Plan, Strategy};
use crate::pipeline;
use crate::util::json::Json;
use crate::util::table::Table;
use crate::util::units::{fmt_bytes, fmt_secs, pct_saving};

/// One strategy's measurements on one model — a cell group of Fig. 4/5.
#[derive(Debug, Clone)]
pub struct StrategyReport {
    pub strategy: Strategy,
    pub latency_secs: f64,
    pub compute_secs: f64,
    pub comm_secs: f64,
    pub peak_memory: u64,
    pub connections: usize,
    pub comm_bytes: u64,
}

impl StrategyReport {
    pub fn from_cost(strategy: Strategy, c: &PlanCost) -> Self {
        Self {
            strategy,
            latency_secs: c.total_secs,
            compute_secs: c.compute_secs,
            comm_secs: c.comm_secs,
            peak_memory: c.memory.peak_footprint(),
            connections: c.connections,
            comm_bytes: c.comm_bytes,
        }
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("strategy", Json::str(self.strategy.name())),
            ("latency_secs", Json::num(self.latency_secs)),
            ("compute_secs", Json::num(self.compute_secs)),
            ("comm_secs", Json::num(self.comm_secs)),
            ("peak_memory_bytes", Json::num(self.peak_memory as f64)),
            ("connections", Json::num(self.connections as f64)),
            ("comm_bytes", Json::num(self.comm_bytes as f64)),
        ])
    }
}

/// Full three-strategy comparison for one model (one group of bars in
/// Fig. 4 and Fig. 5).
#[derive(Debug, Clone)]
pub struct ModelComparison {
    pub model: String,
    pub reports: Vec<StrategyReport>,
}

impl ModelComparison {
    pub fn compute(model: &Model, cluster: &Cluster) -> Self {
        let reports = Strategy::all()
            .iter()
            .map(|&s| {
                let (_, c) = pipeline::plan_and_evaluate(model, cluster, s);
                StrategyReport::from_cost(s, &c)
            })
            .collect();
        Self {
            model: model.name.clone(),
            reports,
        }
    }

    pub fn get(&self, s: Strategy) -> &StrategyReport {
        self.reports.iter().find(|r| r.strategy == s).unwrap()
    }

    /// Fig. 4 caption numbers: IOP saving vs OC and vs CoEdge (percent).
    pub fn iop_latency_savings(&self) -> (f64, f64) {
        let iop = self.get(Strategy::Iop).latency_secs;
        (
            pct_saving(self.get(Strategy::Oc).latency_secs, iop),
            pct_saving(self.get(Strategy::CoEdge).latency_secs, iop),
        )
    }

    /// Fig. 5 caption numbers: IOP peak-memory saving vs CoEdge (percent).
    pub fn iop_memory_saving_vs_coedge(&self) -> f64 {
        pct_saving(
            self.get(Strategy::CoEdge).peak_memory as f64,
            self.get(Strategy::Iop).peak_memory as f64,
        )
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("model", Json::str(self.model.clone())),
            (
                "strategies",
                Json::arr(self.reports.iter().map(|r| r.to_json()).collect()),
            ),
        ])
    }
}

/// Render a set of comparisons as the Fig. 4 latency table.
pub fn latency_table(comparisons: &[ModelComparison]) -> String {
    let mut t = Table::new(&[
        "model",
        "OC",
        "CoEdge",
        "IOP",
        "IOP vs OC",
        "IOP vs CoEdge",
    ]);
    for c in comparisons {
        let (vs_oc, vs_co) = c.iop_latency_savings();
        t.row(vec![
            c.model.clone(),
            fmt_secs(c.get(Strategy::Oc).latency_secs),
            fmt_secs(c.get(Strategy::CoEdge).latency_secs),
            fmt_secs(c.get(Strategy::Iop).latency_secs),
            format!("-{vs_oc:.2}%"),
            format!("-{vs_co:.2}%"),
        ]);
    }
    t.render()
}

/// Render the Fig. 5 peak-memory table.
pub fn memory_table(comparisons: &[ModelComparison]) -> String {
    let mut t = Table::new(&["model", "OC", "CoEdge", "IOP", "IOP vs CoEdge"]);
    for c in comparisons {
        t.row(vec![
            c.model.clone(),
            fmt_bytes(c.get(Strategy::Oc).peak_memory),
            fmt_bytes(c.get(Strategy::CoEdge).peak_memory),
            fmt_bytes(c.get(Strategy::Iop).peak_memory),
            format!("-{:.2}%", c.iop_memory_saving_vs_coedge()),
        ]);
    }
    t.render()
}

/// Per-stage latency breakdown table for one plan.
pub fn stage_breakdown_table(model: &Model, plan: &Plan, cost: &PlanCost) -> String {
    let mut t = Table::new(&["stage", "op", "pre-comm", "comm", "compute"]);
    for (sc, sp) in cost.stages.iter().zip(&plan.stages) {
        t.row(vec![
            format!("{}", sc.op_idx),
            model.ops[sc.op_idx].name.clone(),
            sp.pre_comm.tag().to_string(),
            fmt_secs(sc.comm_secs),
            fmt_secs(sc.compute_secs),
        ]);
    }
    t.render()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::profiles;
    use crate::model::zoo;

    #[test]
    fn comparison_has_all_strategies() {
        let c = ModelComparison::compute(&zoo::lenet(), &profiles::paper_default());
        assert_eq!(c.reports.len(), 3);
        let (vs_oc, vs_co) = c.iop_latency_savings();
        assert!(vs_oc > 0.0 && vs_co > 0.0, "{vs_oc} {vs_co}");
    }

    #[test]
    fn tables_render() {
        let cs = vec![ModelComparison::compute(
            &zoo::lenet(),
            &profiles::paper_default(),
        )];
        assert!(latency_table(&cs).contains("lenet"));
        assert!(memory_table(&cs).contains("CoEdge"));
    }

    #[test]
    fn json_has_three_strategies() {
        let c = ModelComparison::compute(&zoo::lenet(), &profiles::paper_default());
        assert_eq!(c.to_json().get("strategies").as_arr().unwrap().len(), 3);
    }
}
