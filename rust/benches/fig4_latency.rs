//! Fig. 4 regeneration: cooperative inference latency of OC / CoEdge /
//! IOP on LeNet, AlexNet and VGG11 (m=3 paper testbed), with the savings
//! the paper's caption reports, under both the analytic model (eq. 6) and
//! the discrete-event simulator (strict + loose barriers).
//!
//! Run: `cargo bench --bench fig4_latency`

use iop::device::profiles;
use iop::metrics::{latency_table, ModelComparison};
use iop::model::zoo;
use iop::partition::Strategy;
use iop::pipeline;
use iop::sim::{simulate, SimConfig};
use iop::util::table::Table;
use iop::util::units::fmt_secs;

fn main() {
    let cluster = profiles::paper_default();
    println!("== Fig. 4 — inference latency, m=3 paper testbed ==");
    println!(
        "(devices: {:.1} GFLOP/s, {} Mbit/s shared medium, t_est {} ms)\n",
        cluster.devices[0].flops_per_sec / 1e9,
        cluster.bandwidth_bps * 8.0 / 1e6,
        cluster.t_est * 1e3
    );

    let comparisons: Vec<ModelComparison> = zoo::fig4_models()
        .iter()
        .map(|m| ModelComparison::compute(m, &cluster))
        .collect();
    println!("{}", latency_table(&comparisons));

    println!("paper caption: IOP vs OC -31.53 / -21.06 / -12.82 %;");
    println!("               IOP vs CoEdge -12.05 / -16.83 / -6.39 %  (LeNet/AlexNet/VGG11)");
    println!("measured:");
    for c in &comparisons {
        let (vs_oc, vs_co) = c.iop_latency_savings();
        println!("  {:<8} IOP vs OC -{vs_oc:.2}%   IOP vs CoEdge -{vs_co:.2}%", c.model);
    }

    // Cross-check the three timing sources per strategy.
    println!(
        "\n-- analytic vs simulator (strict == analytic by construction; loose = pipelined) --"
    );
    let mut t = Table::new(&["model", "strategy", "analytic", "sim strict", "sim loose"]);
    for model in zoo::fig4_models() {
        for s in Strategy::all() {
            let plan = pipeline::plan(&model, &cluster, s);
            let analytic = iop::cost::evaluate(&model, &cluster, &plan).total_secs;
            let strict = simulate(&model, &cluster, &plan, SimConfig::default()).total_secs;
            let loose = simulate(
                &model,
                &cluster,
                &plan,
                SimConfig {
                    strict_barriers: false,
                    record_trace: false,
                },
            )
            .total_secs;
            t.row(vec![
                model.name.clone(),
                s.name().to_string(),
                fmt_secs(analytic),
                fmt_secs(strict),
                fmt_secs(loose),
            ]);
        }
    }
    println!("{}", t.render());
}
