//! Fig. 5 regeneration: peak per-device memory footprint (paper eq. 1:
//! resident weights + max activation working set) of OC / CoEdge / IOP on
//! the three evaluation models, plus the memory-constrained variant in
//! which eq. (1) forces Algorithm 1 to partition LeNet's classifier (the
//! configuration matching the paper's -49.98% LeNet number).
//!
//! Run: `cargo bench --bench fig5_memory`

use iop::cost::memory::plan_conv_scratch;
use iop::device::{profiles, Cluster, Device};
use iop::exec::compute::centralized_inference_compiled;
use iop::exec::weights::{model_input, WeightBundle};
use iop::exec::{CompiledDevice, ScratchArena};
use iop::metrics::{memory_table, ModelComparison};
use iop::model::zoo;
use iop::partition::Strategy;
use iop::pipeline;
use iop::util::table::Table;
use iop::util::units::{fmt_bytes, pct_saving};

fn main() {
    let cluster = profiles::paper_default();
    println!("== Fig. 5 — peak memory footprint, m=3 paper testbed ==\n");

    let comparisons: Vec<ModelComparison> = zoo::fig4_models()
        .iter()
        .map(|m| ModelComparison::compute(m, &cluster))
        .collect();
    println!("{}", memory_table(&comparisons));
    println!("paper caption: IOP vs CoEdge -49.98 / -21.22 / -40.79 %  (LeNet/AlexNet/VGG11)");
    println!("measured:");
    for c in &comparisons {
        println!("  {:<8} IOP vs CoEdge -{:.2}%", c.model, c.iop_memory_saving_vs_coedge());
    }

    // Per-device breakdown (weights vs activations) for the IOP plans.
    println!("\n-- eq. (1) terms per device (IOP) --");
    let mut t = Table::new(&["model", "device", "Σ weights", "max act", "footprint"]);
    for model in zoo::fig4_models() {
        let plan = pipeline::plan(&model, &cluster, Strategy::Iop);
        let rep = iop::cost::memory::plan_memory(&model, &plan);
        for j in 0..plan.m {
            t.row(vec![
                model.name.clone(),
                format!("dev{j}"),
                fmt_bytes(rep.weights[j]),
                fmt_bytes(rep.peak_activation[j]),
                fmt_bytes(rep.footprint()[j]),
            ]);
        }
    }
    println!("{}", t.render());

    // Transient conv-lowering scratch: the implicit-GEMM (fused im2col)
    // compiled path vs the materialized column matrix it replaced. The
    // analytical model (`cost::memory::plan_conv_scratch`) is printed
    // next to a *measured* high-water arena footprint from a real
    // centralized compiled inference, so the paper's memory figure reads
    // measured numbers, not just the model.
    println!("-- conv-lowering transient scratch (IOP plans, analytical; fused is the default) --");
    let mut t = Table::new(&[
        "model",
        "device",
        "fused peak",
        "materialized peak",
        "saving",
    ]);
    for model in zoo::fig4_models() {
        let plan = pipeline::plan(&model, &cluster, Strategy::Iop);
        let rep = plan_conv_scratch(&model, &plan, 1);
        for j in 0..plan.m {
            t.row(vec![
                model.name.clone(),
                format!("dev{j}"),
                fmt_bytes(rep.fused[j]),
                fmt_bytes(rep.materialized[j]),
                format!(
                    "-{:.2}%",
                    pct_saving(rep.materialized[j] as f64, rep.fused[j] as f64)
                ),
            ]);
        }
    }
    println!("{}", t.render());

    println!("-- measured arena high-water (centralized compiled inference, fused im2col) --");
    let mut t = Table::new(&["model", "measured peak scratch", "vs materialized cols model"]);
    // lenet/vgg_mini/alexnet: the models the compiled executor test
    // suite already runs end to end (vgg11 would prepack ~0.5 GB of
    // weights just to read a scratch counter).
    for model in [zoo::lenet(), zoo::vgg_mini(), zoo::alexnet()] {
        let wb = WeightBundle::generate(&model);
        let cd = CompiledDevice::compile_centralized(&model, &wb, 1);
        let mut arena = ScratchArena::new();
        centralized_inference_compiled(&model, &cd, &model_input(&model), &mut arena);
        // Centralized == one device running every stage Full. The
        // materialized arena's cols and pack buffers grow independently
        // (grow-only), so its peak is the sum of the two per-stage
        // maxima — mirror `ScratchReport`'s accounting, not a max of
        // per-stage sums.
        let slice_bytes = |st, lowering| {
            iop::cost::memory::slice_conv_scratch_bytes(
                &model,
                st,
                &iop::partition::plan::SliceKind::Full,
                lowering,
                1,
            )
        };
        let pack_max = model
            .stages()
            .iter()
            .map(|&st| slice_bytes(st, iop::exec::ConvLowering::Fused))
            .max()
            .unwrap_or(0);
        let cols_max = model
            .stages()
            .iter()
            .map(|&st| {
                slice_bytes(st, iop::exec::ConvLowering::Materialized)
                    - slice_bytes(st, iop::exec::ConvLowering::Fused)
            })
            .max()
            .unwrap_or(0);
        let mat = cols_max + pack_max;
        t.row(vec![
            model.name.clone(),
            fmt_bytes(arena.peak_bytes()),
            format!(
                "-{:.2}%",
                pct_saving(mat as f64, arena.peak_bytes() as f64)
            ),
        ]);
    }
    println!("{}", t.render());

    // Memory-constrained variant: eq. (1) forces FC pairing on LeNet.
    println!("-- memory-constrained LeNet (160 KiB devices): eq. (1) forces FC partitioning --");
    let tight = Cluster::new(
        vec![Device::new(0.6e9, 160 * 1024); 3],
        cluster.bandwidth_bps,
        cluster.t_est,
    );
    let model = zoo::lenet();
    let iop = pipeline::plan_and_evaluate(&model, &tight, Strategy::Iop).1;
    let co = pipeline::plan_and_evaluate(&model, &tight, Strategy::CoEdge).1;
    println!(
        "  IOP peak {}  vs CoEdge peak {}  => saving -{:.2}%  (paper: -49.98%)",
        fmt_bytes(iop.memory.peak_footprint()),
        fmt_bytes(co.memory.peak_footprint()),
        pct_saving(
            co.memory.peak_footprint() as f64,
            iop.memory.peak_footprint() as f64
        )
    );
}
