//! Fig. 6 regeneration: inference time of VGG11/13/16/19 under OC /
//! CoEdge / IOP as the connection establishment latency sweeps 1–8 ms
//! (m=3 paper testbed) — the series the paper plots, plus the saving
//! ranges its text quotes.
//!
//! Run: `cargo bench --bench fig6_vgg_sweep`

use iop::device::profiles;
use iop::model::zoo;
use iop::partition::Strategy;
use iop::pipeline;
use iop::util::table::Table;
use iop::util::units::{fmt_secs, pct_saving};

fn main() {
    println!("== Fig. 6 — VGG family vs connection establishment latency ==\n");
    let t_ests_ms: Vec<f64> = (1..=8).map(|t| t as f64).collect();

    let mut table = Table::new(&[
        "model",
        "t_est(ms)",
        "OC",
        "CoEdge",
        "IOP",
        "IOP vs OC",
        "IOP vs CoEdge",
    ]);
    let mut ranges = Vec::new();

    for model in zoo::fig6_models() {
        let mut vs_oc = Vec::new();
        let mut vs_best = Vec::new();
        for &t in &t_ests_ms {
            let cluster = profiles::paper_with_t_est(t * 1e-3);
            let oc = pipeline::plan_and_evaluate(&model, &cluster, Strategy::Oc).1.total_secs;
            let co = pipeline::plan_and_evaluate(&model, &cluster, Strategy::CoEdge).1.total_secs;
            let iop = pipeline::plan_and_evaluate(&model, &cluster, Strategy::Iop).1.total_secs;
            assert!(iop <= co.min(oc), "IOP must be minimal (paper claim)");
            vs_oc.push(pct_saving(oc, iop));
            vs_best.push(pct_saving(co.min(oc), iop));
            table.row(vec![
                model.name.clone(),
                format!("{t}"),
                fmt_secs(oc),
                fmt_secs(co),
                fmt_secs(iop),
                format!("-{:.2}%", pct_saving(oc, iop)),
                format!("-{:.2}%", pct_saving(co, iop)),
            ]);
        }
        ranges.push((model.name.clone(), vs_oc, vs_best));
    }
    println!("{}", table.render());

    println!("IOP saving vs OC across the sweep (paper quotes vs-range per model):");
    let paper = [
        ("vgg11", "14.51%..26.74%"),
        ("vgg13", "12.99%..24.99%"),
        ("vgg16", "3.34%..31.01%"),
        ("vgg19", "15.01%..34.87%"),
    ];
    for ((name, vs_oc, vs_best), (pname, pband)) in ranges.iter().zip(paper.iter()) {
        assert_eq!(name, pname);
        println!(
            "  {:<6} measured vs OC {:.2}%..{:.2}% (vs best baseline {:.2}%..{:.2}%); paper: {}",
            name,
            vs_oc.first().unwrap(),
            vs_oc.last().unwrap(),
            vs_best.first().unwrap(),
            vs_best.last().unwrap(),
            pband
        );
        // the paper's trend: larger t_est, larger advantage
        assert!(
            vs_oc.last().unwrap() > vs_oc.first().unwrap(),
            "{name}: saving must grow with t_est"
        );
    }
}
