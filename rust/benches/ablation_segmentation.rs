//! Ablation: how good is the paper's greedy Algorithm 1?
//!
//! Compares, per model × t_est, the end-to-end latency of IOP plans built
//! from (a) greedy (Algorithm 1), (b) exact DP, (c) exhaustive oracle,
//! (d) all-singles (≈ CoEdge), (e) all-pairs-where-possible — plus solver
//! runtime microbenchmarks (the planner itself must be cheap enough for
//! on-device replanning).
//!
//! Run: `cargo bench --bench ablation_segmentation`

use iop::bench::Bencher;
use iop::device::profiles;
use iop::model::zoo;
use iop::partition::iop::pairable;
use iop::partition::Segment;
use iop::segmentation::{dp, exhaustive, greedy, segmentation_cost};
use iop::util::table::Table;
use iop::util::units::fmt_secs;

fn all_singles(n: usize) -> Vec<Segment> {
    (0..n).map(Segment::Single).collect()
}

fn eager_pairs(model: &iop::model::Model) -> Vec<Segment> {
    let stages = model.stages();
    let mut out = Vec::new();
    let mut i = 0;
    while i < stages.len() {
        if i + 1 < stages.len() && pairable(model, stages[i], stages[i + 1]) {
            out.push(Segment::Pair(i));
            i += 2;
        } else {
            out.push(Segment::Single(i));
            i += 1;
        }
    }
    out
}

fn main() {
    println!("== Ablation: segmentation solvers ==\n");
    let mut t = Table::new(&[
        "model",
        "t_est",
        "greedy (Alg.1)",
        "DP (exact)",
        "exhaustive",
        "all-singles",
        "eager-pairs",
        "greedy gap",
    ]);
    for model in zoo::all_models() {
        for t_ms in [1.0, 4.0, 8.0] {
            let cluster = profiles::paper_with_t_est(t_ms * 1e-3);
            let n = model.stages().len();
            let g = segmentation_cost(&model, &cluster, &greedy(&model, &cluster));
            let d = segmentation_cost(&model, &cluster, &dp(&model, &cluster));
            let e = segmentation_cost(&model, &cluster, &exhaustive(&model, &cluster));
            let s = segmentation_cost(&model, &cluster, &all_singles(n));
            let p = segmentation_cost(&model, &cluster, &eager_pairs(&model));
            t.row(vec![
                model.name.clone(),
                format!("{t_ms} ms"),
                fmt_secs(g),
                fmt_secs(d),
                fmt_secs(e),
                fmt_secs(s),
                fmt_secs(p),
                format!("+{:.2}%", (g / d - 1.0) * 100.0),
            ]);
        }
    }
    println!("{}", t.render());

    println!("-- solver runtime (planning cost itself) --");
    let cluster = profiles::paper_default();
    let b = Bencher::default();
    for model in [zoo::lenet(), zoo::vgg19()] {
        b.report(&format!("greedy({})", model.name), || greedy(&model, &cluster));
        b.report(&format!("dp({})", model.name), || dp(&model, &cluster));
        b.report(&format!("exhaustive({})", model.name), || {
            exhaustive(&model, &cluster)
        });
    }
}
