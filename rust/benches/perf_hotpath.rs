//! §Perf hot-path microbenchmarks (EXPERIMENTS.md §Perf is generated from
//! these numbers): the L3 coordinator's request-path costs —
//!
//!  * plan construction per strategy (replanning cost),
//!  * plan cost evaluation (the inner loop of every solver),
//!  * discrete-event simulation throughput,
//!  * weight-bundle generation + slicing (deployment-time),
//!  * executor compute backends: reference tensor ops vs the blocked
//!    im2col+GEMM fast kernels (serial and multi-threaded),
//!  * end-to-end distributed inference on both host backends (thread
//!    harness overhead + compute),
//!  * the quantized tier: the compiled steady-state case again at
//!    --dtype i8, paired with its f32 twin for the int8 speedup,
//!  * steady-state serving throughput: closed-loop submit/collect at
//!    inflight=1 vs inflight=m over one warmed session (the pipelining
//!    win, measured — see EXPERIMENTS.md §Perf "Pipelined serving").
//!
//! Run: `cargo bench --bench perf_hotpath`
//!
//! Emits `BENCH_hotpath.json` (median + MAD per case) at the repo root —
//! override the path with `BENCH_HOTPATH_OUT`, and set `IOP_BENCH_QUICK=1`
//! for the CI smoke configuration (shorter warmup/measure windows).

use iop::bench::{BenchReport, Bencher, Stats};
use iop::device::profiles;
use iop::exec::backend::{available_threads, ComputeBackend};
use iop::exec::compute::{
    centralized_inference, centralized_inference_compiled, centralized_inference_with,
};
use iop::exec::weights::{model_input, WeightBundle};
use iop::exec::{
    run_plan, serve_closed_loop, Backend, CompiledDevice, ExecOptions, ExecSession, ScratchArena,
    ServeOptions,
};
use iop::model::zoo;
use iop::partition::Strategy;
use iop::pipeline;
use iop::sim::{simulate, SimConfig};
use iop::tensor::kernels;

fn main() {
    let cluster = profiles::paper_default();
    // Name the dispatched code path up front: every GEMM/matvec/pool
    // number below is attributable to this microkernel.
    println!(
        "GEMM microkernel: {} (supported on this CPU: {})",
        kernels::selected().describe(),
        kernels::supported()
            .iter()
            .map(|k| k.describe())
            .collect::<Vec<_>>()
            .join(", ")
    );
    let quick = std::env::var("IOP_BENCH_QUICK").is_ok();
    let b = if quick {
        Bencher::quick()
    } else {
        Bencher::default()
    };
    let mut rep = BenchReport::new();
    macro_rules! bench {
        ($name:expr, $f:expr) => {{
            let name: &str = &$name;
            let st = b.report(name, $f);
            rep.add(name, st);
        }};
    }

    println!("== planner throughput ==");
    for model in [zoo::lenet(), zoo::alexnet(), zoo::vgg19()] {
        for s in Strategy::all() {
            bench!(format!("plan {} {}", model.name, s.name()), || {
                pipeline::plan(&model, &cluster, s)
            });
        }
    }

    println!("\n== cost evaluation (solver inner loop) ==");
    for model in [zoo::lenet(), zoo::vgg19()] {
        let plan = pipeline::plan(&model, &cluster, Strategy::Iop);
        bench!(format!("evaluate {}", model.name), || {
            iop::cost::evaluate(&model, &cluster, &plan)
        });
    }

    println!("\n== simulator throughput ==");
    for model in [zoo::alexnet(), zoo::vgg19()] {
        let plan = pipeline::plan(&model, &cluster, Strategy::Iop);
        let cfg = SimConfig {
            strict_barriers: false,
            record_trace: false,
        };
        bench!(format!("simulate {} (no trace)", model.name), || {
            simulate(&model, &cluster, &plan, cfg)
        });
        let cfg_t = SimConfig {
            strict_barriers: false,
            record_trace: true,
        };
        bench!(format!("simulate {} (trace)", model.name), || {
            simulate(&model, &cluster, &plan, cfg_t)
        });
    }

    println!("\n== deployment-time: weights ==");
    for model in [zoo::lenet(), zoo::vgg_mini()] {
        bench!(format!("WeightBundle::generate {}", model.name), || {
            WeightBundle::generate(&model)
        });
    }

    println!("\n== compute backends (centralized vgg_mini) ==");
    let model = zoo::vgg_mini();
    let wb = WeightBundle::generate(&model);
    let x = model_input(&model);
    bench!("centralized vgg_mini (reference ops)", || {
        centralized_inference(&model, &wb, &x)
    });
    bench!("centralized vgg_mini (fast ops)", || {
        centralized_inference_with(ComputeBackend::fast(), &model, &wb, &x)
    });
    let threads = available_threads();
    bench!(format!("centralized vgg_mini (fast ops, {threads} threads)"), || {
        centralized_inference_with(ComputeBackend::fast_parallel(), &model, &wb, &x)
    });
    // Compiled plan: weights prepacked once, im2col/GEMM scratch reused
    // across iterations out of one arena (the serving-loop shape).
    let compiled = CompiledDevice::compile_centralized(&model, &wb, 1);
    let mut arena = ScratchArena::new();
    bench!("centralized vgg_mini (compiled ops)", || {
        centralized_inference_compiled(&model, &compiled, &x, &mut arena)
    });
    if let (Some(rf), Some(fast)) = (
        rep.get("centralized vgg_mini (reference ops)"),
        rep.get("centralized vgg_mini (fast ops)"),
    ) {
        println!(
            "fast-backend speedup vs reference (vgg_mini, 1 thread): {:.1}x",
            rf.median / fast.median
        );
    }
    if let (Some(fast), Some(comp)) = (
        rep.get("centralized vgg_mini (fast ops)"),
        rep.get("centralized vgg_mini (compiled ops)"),
    ) {
        println!(
            "compiled-plan speedup vs fast (vgg_mini, 1 thread): {:.2}x",
            fast.median / comp.median
        );
    }

    // Case-name convention for the end-to-end sections: "(cold: ...)"
    // cases deliberately pay session spawn (worker threads + compile)
    // inside the measured closure via `run_plan`; every "(steady)" case
    // reuses ONE session created outside the closure — never mix the
    // two, or worker spawn cost leaks into steady-state numbers.
    println!("\n== end-to-end distributed inference (reference backend) ==");
    for s in Strategy::all() {
        let model = zoo::lenet();
        let plan = pipeline::plan(&model, &cluster, s);
        bench!(format!("run_plan lenet {} (cold: spawn+infer)", s.name()), || {
            run_plan(&model, &plan, &ExecOptions::default()).unwrap()
        });
        let mut session = ExecSession::new(&model, &plan, Backend::Reference).unwrap();
        let input = model_input(&model);
        bench!(format!("session.infer lenet {} (steady)", s.name()), || {
            session.infer(input.clone()).unwrap()
        });
    }

    println!("\n== end-to-end distributed inference (fast backend) ==");
    for s in Strategy::all() {
        let model = zoo::vgg_mini();
        let plan = pipeline::plan(&model, &cluster, s);
        let mut session =
            ExecSession::new(&model, &plan, Backend::Fast { threads: 1 }).unwrap();
        let input = model_input(&model);
        bench!(format!("session.infer vgg_mini {} (fast, steady)", s.name()), || {
            session.infer(input.clone()).unwrap()
        });
    }

    println!("\n== end-to-end distributed inference (compiled plans) ==");
    for s in Strategy::all() {
        let model = zoo::vgg_mini();
        let plan = pipeline::plan(&model, &cluster, s);
        let mut session =
            ExecSession::new(&model, &plan, Backend::Compiled { threads: 1 }).unwrap();
        let input = model_input(&model);
        bench!(format!("session.infer vgg_mini {} (compiled, steady)", s.name()), || {
            session.infer(input.clone()).unwrap()
        });
    }
    if let (Some(fast), Some(comp)) = (
        rep.get("session.infer vgg_mini IOP (fast, steady)"),
        rep.get("session.infer vgg_mini IOP (compiled, steady)"),
    ) {
        println!(
            "compiled-plan steady-state speedup vs fast (vgg_mini IOP): {:.2}x",
            fast.median / comp.median
        );
    }

    // SIMD dispatch ablation: the same compiled steady-state case with
    // the microkernel forced to the portable scalar tile. Paired with
    // the dispatched case above (same perf-smoke run), this tracks the
    // per-core SIMD win in BENCH_hotpath.json; CI gates the pair at
    // >= 2x on AVX2 runners. Forcing happens between sessions — the
    // scalar session packs AND runs scalar, then auto-detection is
    // restored before any later case.
    println!("\n== SIMD microkernel dispatch (compiled steady-state, scalar-forced) ==");
    {
        let model = zoo::vgg_mini();
        let plan = pipeline::plan(&model, &cluster, Strategy::Iop);
        kernels::force(Some(kernels::by_name("scalar").expect("scalar always compiled in")));
        {
            let mut session =
                ExecSession::new(&model, &plan, Backend::Compiled { threads: 1 }).unwrap();
            let input = model_input(&model);
            bench!("session.infer vgg_mini IOP (compiled, steady, scalar kernel)", || {
                session.infer(input.clone()).unwrap()
            });
        }
        kernels::force(None);
        if let (Some(scalar), Some(disp)) = (
            rep.get("session.infer vgg_mini IOP (compiled, steady, scalar kernel)"),
            rep.get("session.infer vgg_mini IOP (compiled, steady)"),
        ) {
            println!(
                "SIMD dispatch speedup vs scalar ({}, vgg_mini IOP compiled steady): {:.2}x",
                kernels::selected().describe(),
                scalar.median / disp.median
            );
        }
    }

    // Conv-lowering ablation: the same compiled steady-state case with
    // im2col forced back to the materialized column matrix (the PR 2–4
    // behavior). The default "(compiled, steady)" case above runs the
    // fused implicit-GEMM path, so the pair isolates both the latency
    // and the peak-transient-scratch effect of killing the cols buffer.
    // Forcing happens between sessions, exactly like the scalar twin.
    println!("\n== conv lowering (compiled steady-state, materialized-im2col-forced) ==");
    {
        use iop::exec::{force_lowering, ConvLowering};
        let model = zoo::vgg_mini();
        let plan = pipeline::plan(&model, &cluster, Strategy::Iop);
        let input = model_input(&model);
        let fused_peak = {
            let mut session =
                ExecSession::new(&model, &plan, Backend::Compiled { threads: 1 }).unwrap();
            let r = session.infer(input.clone()).unwrap();
            *r.stats.peak_scratch_bytes.iter().max().unwrap()
        };
        force_lowering(Some(ConvLowering::Materialized));
        let mat_peak;
        {
            let mut session =
                ExecSession::new(&model, &plan, Backend::Compiled { threads: 1 }).unwrap();
            mat_peak = {
                let r = session.infer(input.clone()).unwrap();
                *r.stats.peak_scratch_bytes.iter().max().unwrap()
            };
            bench!("session.infer vgg_mini IOP (compiled, steady, materialized im2col)", || {
                session.infer(input.clone()).unwrap()
            });
        }
        force_lowering(None);
        println!(
            "peak transient scratch (max over devices): fused {} vs materialized {} (-{:.1}%)",
            iop::util::units::fmt_bytes(fused_peak),
            iop::util::units::fmt_bytes(mat_peak),
            (1.0 - fused_peak as f64 / mat_peak as f64) * 100.0
        );
        if let (Some(mat), Some(fused)) = (
            rep.get("session.infer vgg_mini IOP (compiled, steady, materialized im2col)"),
            rep.get("session.infer vgg_mini IOP (compiled, steady)"),
        ) {
            println!(
                "fused im2col speedup vs materialized (vgg_mini IOP compiled steady): {:.2}x",
                mat.median / fused.median
            );
        }
    }

    // Quantized-tier twin: the same compiled steady-state case with the
    // session opened at --dtype i8 (symmetric per-channel int8 panels,
    // i8×i8→i32 microkernels, dequant+bias+ReLU fused into the f32
    // writeback). Paired with the f32 "(compiled, steady)" case above
    // in the same run; CI gates the pair at >= 1.3x on AVX2 runners,
    // where madd-based i8 tiles beat the FMA f32 tiles on arithmetic
    // density and the packed panels are ~4x lighter on cache.
    println!("\n== quantized tier (compiled steady-state, int8) ==");
    {
        use iop::exec::SessionOptions;
        use iop::tensor::quant::Dtype;
        let model = zoo::vgg_mini();
        let mut session = ExecSession::open(
            &model,
            &cluster,
            Strategy::Iop,
            SessionOptions {
                backend: Backend::Compiled { threads: 1 },
                dtype: Dtype::I8,
                ..SessionOptions::default()
            },
        )
        .unwrap();
        println!(
            "i8 microkernel: {} | packed weights: {}",
            kernels::selected_i8().describe(),
            iop::util::units::fmt_bytes(session.packed_bytes())
        );
        let input = model_input(&model);
        bench!("session.infer vgg_mini IOP (compiled, steady, i8)", || {
            session.infer(input.clone()).unwrap()
        });
        if let (Some(f32c), Some(i8c)) = (
            rep.get("session.infer vgg_mini IOP (compiled, steady)"),
            rep.get("session.infer vgg_mini IOP (compiled, steady, i8)"),
        ) {
            println!(
                "int8 steady-state speedup vs f32 ({}, vgg_mini IOP compiled): {:.2}x",
                kernels::selected_i8().describe(),
                f32c.median / i8c.median
            );
        }
    }

    // Steady-state serving *throughput*: a closed loop of N requests at
    // a fixed in-flight depth over ONE warmed session per backend (no
    // per-run session spawn — the inflight=1 / inflight=m pair differs
    // only in pipelining). Samples are seconds *per request*
    // (wall / N), so the printed /s rate is requests/sec.
    println!("\n== steady-state serving throughput (closed loop, one session per backend) ==");
    {
        let model = zoo::vgg_mini();
        let plan = pipeline::plan(&model, &cluster, Strategy::Iop);
        let m = plan.m;
        let (serve_reqs, serve_reps) = if quick { (24, 3) } else { (96, 5) };
        for (label, backend) in [
            ("fast", Backend::Fast { threads: 1 }),
            ("compiled", Backend::Compiled { threads: 1 }),
        ] {
            let mut session = ExecSession::new(&model, &plan, backend).unwrap();
            let input = model_input(&model);
            for _ in 0..m {
                session.infer(input.clone()).unwrap(); // warm arenas
            }
            for depth in [1usize, m] {
                let name = format!("serve vgg_mini IOP ({label}, steady, inflight={depth})");
                let mut samples = Vec::with_capacity(serve_reps);
                for _ in 0..serve_reps {
                    let r = serve_closed_loop(
                        &mut session,
                        &ServeOptions {
                            requests: serve_reqs,
                            inflight: depth,
                            warmup: 0,
                        },
                        |_| input.clone(),
                        |_, _| {},
                    )
                    .unwrap();
                    samples.push(r.wall_secs / serve_reqs as f64);
                }
                let st = Stats::from_samples(samples);
                println!(
                    "bench {name:<52} median {:>12}/req ({:>8} req/s)  n={}",
                    iop::util::units::fmt_secs(st.median),
                    format!("{:.1}", st.per_sec()),
                    st.samples
                );
                rep.add(&name, st);
            }
        }
        for label in ["fast", "compiled"] {
            if let (Some(serial), Some(piped)) = (
                rep.get(&format!("serve vgg_mini IOP ({label}, steady, inflight=1)")),
                rep.get(&format!("serve vgg_mini IOP ({label}, steady, inflight={m})")),
            ) {
                println!(
                    "pipelined throughput vs serial ({label}, inflight {m} vs 1): {:.2}x",
                    serial.median / piped.median
                );
            }
        }
    }

    // Cross-request batching: the same closed loop at inflight=8 with
    // the batcher coalescing up to 8 requests into one batched GEMM
    // dispatch per stage, vs batch=1, over ONE warmed session
    // (set_batch_policy swaps the policy between runs, so the pair
    // differs only in coalescing). Samples are seconds per request.
    println!("\n== cross-request batching throughput (closed loop, one warmed session) ==");
    {
        let model = zoo::vgg_mini();
        let plan = pipeline::plan(&model, &cluster, Strategy::Iop);
        let (serve_reqs, serve_reps) = if quick { (24, 3) } else { (96, 5) };
        let mut session =
            ExecSession::new(&model, &plan, Backend::Compiled { threads: 1 }).unwrap();
        let input = model_input(&model);
        for batch in [1usize, 8] {
            session.set_batch_policy(batch, None);
            // Unsampled warm run per policy: the batched path grows its
            // own pack/output arenas on first contact.
            serve_closed_loop(
                &mut session,
                &ServeOptions {
                    requests: 8,
                    inflight: 8,
                    warmup: 0,
                },
                |_| input.clone(),
                |_, _| {},
            )
            .unwrap();
            let name = format!("serve vgg_mini IOP (compiled, steady, batch={batch})");
            let mut samples = Vec::with_capacity(serve_reps);
            for _ in 0..serve_reps {
                let r = serve_closed_loop(
                    &mut session,
                    &ServeOptions {
                        requests: serve_reqs,
                        inflight: 8,
                        warmup: 0,
                    },
                    |_| input.clone(),
                    |_, _| {},
                )
                .unwrap();
                samples.push(r.wall_secs / serve_reqs as f64);
            }
            let st = Stats::from_samples(samples);
            println!(
                "bench {name:<52} median {:>12}/req ({:>8} req/s)  n={}",
                iop::util::units::fmt_secs(st.median),
                format!("{:.1}", st.per_sec()),
                st.samples
            );
            rep.add(&name, st);
        }
        if let (Some(one), Some(batched)) = (
            rep.get("serve vgg_mini IOP (compiled, steady, batch=1)"),
            rep.get("serve vgg_mini IOP (compiled, steady, batch=8)"),
        ) {
            println!(
                "batched throughput vs batch=1 (compiled, inflight 8): {:.2}x",
                one.median / batched.median
            );
        }
    }

    let default_out = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_hotpath.json");
    let out = std::env::var("BENCH_HOTPATH_OUT").unwrap_or_else(|_| default_out.to_string());
    rep.write(&out).expect("writing BENCH_hotpath.json");
    println!("\nwrote {out}");
}
