//! §Perf hot-path microbenchmarks (EXPERIMENTS.md §Perf is generated from
//! these numbers): the L3 coordinator's request-path costs —
//!
//!  * plan construction per strategy (replanning cost),
//!  * plan cost evaluation (the inner loop of every solver),
//!  * discrete-event simulation throughput,
//!  * weight-bundle generation + slicing (deployment-time),
//!  * reference tensor ops (the distributed executor's compute),
//!  * end-to-end reference distributed inference (thread harness
//!    overhead + compute).
//!
//! Run: `cargo bench --bench perf_hotpath`

use iop::bench::Bencher;
use iop::device::profiles;
use iop::exec::weights::{model_input, WeightBundle};
use iop::exec::{run_plan, ExecOptions};
use iop::model::zoo;
use iop::partition::Strategy;
use iop::pipeline;
use iop::sim::{simulate, SimConfig};

fn main() {
    let cluster = profiles::paper_default();
    let b = Bencher::default();

    println!("== planner throughput ==");
    for model in [zoo::lenet(), zoo::alexnet(), zoo::vgg19()] {
        for s in Strategy::all() {
            b.report(&format!("plan {} {}", model.name, s.name()), || {
                pipeline::plan(&model, &cluster, s)
            });
        }
    }

    println!("\n== cost evaluation (solver inner loop) ==");
    for model in [zoo::lenet(), zoo::vgg19()] {
        let plan = pipeline::plan(&model, &cluster, Strategy::Iop);
        b.report(&format!("evaluate {}", model.name), || {
            iop::cost::evaluate(&model, &cluster, &plan)
        });
    }

    println!("\n== simulator throughput ==");
    for model in [zoo::alexnet(), zoo::vgg19()] {
        let plan = pipeline::plan(&model, &cluster, Strategy::Iop);
        let cfg = SimConfig {
            strict_barriers: false,
            record_trace: false,
        };
        b.report(&format!("simulate {} (no trace)", model.name), || {
            simulate(&model, &cluster, &plan, cfg)
        });
        let cfg_t = SimConfig {
            strict_barriers: false,
            record_trace: true,
        };
        b.report(&format!("simulate {} (trace)", model.name), || {
            simulate(&model, &cluster, &plan, cfg_t)
        });
    }

    println!("\n== deployment-time: weights ==");
    for model in [zoo::lenet(), zoo::vgg_mini()] {
        b.report(&format!("WeightBundle::generate {}", model.name), || {
            WeightBundle::generate(&model)
        });
    }

    println!("\n== reference compute (executor backend) ==");
    let model = zoo::vgg_mini();
    let wb = WeightBundle::generate(&model);
    let x = model_input(&model);
    b.report("centralized vgg_mini (reference ops)", || {
        iop::exec::compute::centralized_inference(&model, &wb, &x)
    });

    println!("\n== end-to-end distributed inference (reference backend) ==");
    for s in Strategy::all() {
        let model = zoo::lenet();
        let plan = pipeline::plan(&model, &cluster, s);
        b.report(&format!("run_plan lenet {} (cold: spawn+infer)", s.name()), || {
            run_plan(&model, &plan, &ExecOptions::default()).unwrap()
        });
        let mut session =
            iop::exec::ExecSession::new(&model, &plan, iop::exec::Backend::Reference).unwrap();
        let input = model_input(&model);
        b.report(&format!("session.infer lenet {} (steady)", s.name()), || {
            session.infer(input.clone()).unwrap()
        });
    }
}
