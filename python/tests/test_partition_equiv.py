"""The paper's partition algebra, verified at the kernel level:

* OC shards concatenated  == full operator output;
* IC partial sums reduced (+bias/ReLU after) == full operator output;
* row windows convolved with materialized padding == full conv rows.

These are the python counterparts of rust's tensor::ops partition tests,
and exactly the identities the AOT shard executables rely on.
"""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile import weights as W
from compile.kernels import conv2d, dense, ref

SET = dict(max_examples=20, deadline=None)


def arr(name, shape):
    return jnp.asarray(W.named_tensor(name, int(np.prod(shape))).reshape(shape))


def splits(n, parts):
    base = n // parts
    counts = [base] * parts
    for i in range(n - base * parts):
        counts[i] += 1
    out, s = [], 0
    for c in counts:
        out.append((s, c))
        s += c
    return [r for r in out if r[1] > 0]


@given(
    c_in=st.integers(2, 6),
    c_out=st.integers(3, 12),
    parts=st.integers(2, 4),
    seed=st.integers(0, 999),
)
@settings(**SET)
def test_conv_oc_concat_equals_full(c_in, c_out, parts, seed):
    x = arr(f"i{seed}", (c_in, 9, 9))
    w = arr(f"w{seed}", (c_out, c_in, 3, 3))
    b = arr(f"b{seed}", (c_out,))
    full = conv2d(x, w, b, pad_h=1, pad_w=1, relu=True)
    shards = [
        conv2d(x, w[s : s + n], b[s : s + n], pad_h=1, pad_w=1, relu=True)
        for s, n in splits(c_out, parts)
    ]
    np.testing.assert_allclose(jnp.concatenate(shards, 0), full, rtol=1e-5, atol=1e-5)


@given(
    c_in=st.integers(3, 9),
    c_out=st.integers(2, 6),
    parts=st.integers(2, 4),
    seed=st.integers(0, 999),
)
@settings(**SET)
def test_conv_ic_partials_reduce_to_full(c_in, c_out, parts, seed):
    x = arr(f"ii{seed}", (c_in, 8, 8))
    w = arr(f"iw{seed}", (c_out, c_in, 3, 3))
    b = arr(f"ib{seed}", (c_out,))
    full = ref.conv2d_ref(x, w, b, pad_h=1, pad_w=1, relu=True)
    partials = [
        conv2d(x[s : s + n], w[:, s : s + n], None, pad_h=1, pad_w=1, relu=False)
        for s, n in splits(c_in, parts)
    ]
    raw = sum(partials)
    y = jnp.maximum(raw + b[:, None, None], 0.0)
    np.testing.assert_allclose(y, full, rtol=1e-4, atol=1e-5)


@given(
    feats=st.integers(4, 64),
    c_out=st.integers(2, 32),
    parts=st.integers(2, 4),
    seed=st.integers(0, 999),
)
@settings(**SET)
def test_dense_ic_partials_reduce_to_full(feats, c_out, parts, seed):
    x = arr(f"dx{seed}", (feats,))
    w = arr(f"dw{seed}", (c_out, feats))
    b = arr(f"db{seed}", (c_out,))
    full = ref.dense_ref(x, w, b, relu=True)
    partials = [dense(x[s : s + n], w[:, s : s + n], None) for s, n in splits(feats, parts)]
    y = jnp.maximum(sum(partials) + b, 0.0)
    np.testing.assert_allclose(y, full, rtol=1e-4, atol=1e-5)


@given(
    rows=st.integers(6, 14),
    parts=st.integers(2, 3),
    pad=st.integers(0, 1),
    seed=st.integers(0, 999),
)
@settings(**SET)
def test_row_windows_concat_to_full_conv(rows, parts, pad, seed):
    """CoEdge semantics: output rows [a,b) need input rows
    [a-pad, b+k-1-pad); windows are zero-filled outside the image and the
    shard convolves with pad_h=0."""
    k = 3
    c_in, c_out = 2, 4
    x = arr(f"rx{seed}", (c_in, rows, 7))
    w = arr(f"rw{seed}", (c_out, c_in, k, k))
    b = arr(f"rb{seed}", (c_out,))
    full = ref.conv2d_ref(x, w, b, pad_h=pad, pad_w=pad, relu=True)
    out_rows = full.shape[1]

    shards = []
    for a, n in splits(out_rows, parts):
        lo = a - pad
        hi = (a + n - 1) + k - pad
        win_h = hi - lo
        window = jnp.zeros((c_in, win_h, x.shape[2]), jnp.float32)
        src_lo, src_hi = max(lo, 0), min(hi, rows)
        window = window.at[:, src_lo - lo : src_hi - lo].set(x[:, src_lo:src_hi])
        shards.append(conv2d(window, w, b, pad_h=0, pad_w=pad, relu=True))
    got = jnp.concatenate(shards, 1)
    np.testing.assert_allclose(got, full, rtol=1e-5, atol=1e-5)
