"""AOT pipeline tests. The heavyweight export is exercised by
``make artifacts``; here we verify the manifest contract and a
self-contained mini export round-trip."""

import json
import os

import numpy as np
import pytest

from compile import model as M
from compile.aot import Exporter, out_shape_of, to_hlo_text
from compile.partition import build_step, build_tail

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def test_to_hlo_text_smoke(tmp_path):
    import jax.numpy as jnp

    def fn(x, y):
        return (jnp.dot(x, y) + 1.0,)

    text = to_hlo_text(fn, [(4,), (4,)])
    assert "HloModule" in text


def test_exporter_dedup(tmp_path):
    import jax.numpy as jnp

    ex = Exporter(str(tmp_path))

    def fn(x):
        return (x * 2.0,)

    ex.add("a", fn, [(8,)], (8,))
    ex.add("b", fn, [(8,)], (8,))
    ex.write_manifest()
    man = json.load(open(tmp_path / "manifest.json"))
    assert man["entries"]["a"]["file"] == man["entries"]["b"]["file"]
    files = [f for f in os.listdir(tmp_path) if f.endswith(".hlo.txt")]
    assert len(files) == 1


def test_step_builder_shapes_oc():
    md = M.lenet()
    dev = {"kind": "oc", "start": 0, "count": 2}
    fn, shapes = build_step(md, 0, 2, dev, (1, 28, 28))
    assert shapes[0] == (1, 28, 28)
    out = out_shape_of(md, 0, 2, dev, (1, 28, 28))
    assert out == (2, 14, 14)  # conv1 (pad 2) + pool1, 2 channels


def test_step_builder_shapes_rows():
    md = M.lenet()
    dev = {"kind": "rows", "start": 0, "count": 5, "win_lo": -2, "win_hi": 12}
    out = out_shape_of(md, 0, 2, dev, (1, 28, 28))
    # window 14 rows, conv k5 pad_h0 -> 10 rows, pool2 -> 5 rows
    assert out == (6, 5, 14)


def test_tail_builder_applies_bias_relu():
    import jax.numpy as jnp

    md = M.lenet()
    fn, shapes = build_tail(md, 2, 5, (16, 10, 10))  # conv2+pool2+flatten
    raw = jnp.full((16, 10, 10), -1.0)
    b = jnp.zeros((16,))
    (y,) = fn(raw, b)
    assert y.shape == (400,)
    assert float(jnp.abs(y).max()) == 0.0  # relu clamps the -1s


@pytest.mark.skipif(
    not os.path.exists(os.path.join(ART, "manifest.json")),
    reason="artifacts not built (run `make artifacts`)",
)
def test_manifest_contract():
    man = json.load(open(os.path.join(ART, "manifest.json")))
    entries = man["entries"]
    assert any(k.endswith("/central") for k in entries)
    for key, e in entries.items():
        path = os.path.join(ART, e["file"])
        assert os.path.exists(path), f"{key}: missing {e['file']}"
        assert isinstance(e["inputs"], list) and isinstance(e["output"], list)
    # every strategy of every exported model has stage-0 shards
    plans = json.load(open(os.path.join(ART, "plans.json")))
    for model, doc in plans.items():
        for strat in doc["strategies"]:
            assert any(
                k.startswith(f"{model}/{strat}/s0/") for k in entries
            ), f"{model}/{strat} has no stage-0 shards"
