"""Mirrored-PRNG tests: python must generate bit-identical streams to
rust/src/util/prng.rs (asserted there against the same frozen goldens)."""

import numpy as np

from compile import weights as W


def test_fnv1a_known_vectors():
    assert W.fnv1a("") == 0xCBF29CE484222325
    assert W.fnv1a("a") == 0xAF63DC4C8601EC8C
    assert W.fnv1a("foobar") == 0x85944171F73967E8


def test_splitmix_reference_sequence():
    r = W.SplitMix64(0)
    assert r.next_u64() == 0xE220A8397B1DCDAF
    assert r.next_u64() == 0x6E789E6AA1B965F4
    assert r.next_u64() == 0x06C45D188009454F


def test_golden_cross_language():
    # Frozen in rust/src/util/prng.rs::golden_values_match_python.
    v = W.named_tensor("golden", 4, 1.0)
    expect = np.array([0.32074094, 0.9703958, -0.4739381, 0.18444812], np.float32)
    np.testing.assert_allclose(v, expect, rtol=0, atol=1e-7)


def test_uniform01_range_and_determinism():
    a = W.uniform01("x", 10_000)
    b = W.uniform01("x", 10_000)
    np.testing.assert_array_equal(a, b)
    assert a.min() >= 0.0 and a.max() < 1.0
    assert abs(a.mean() - 0.5) < 0.02


def test_named_tensor_scale_and_keying():
    a = W.named_tensor("k1", 256, 0.05)
    b = W.named_tensor("k2", 256, 0.05)
    assert abs(a).max() <= 0.05
    assert not np.array_equal(a, b)


def test_conv_weight_shape():
    w = W.conv_weight("m", "c1", 6, 3, 5, 5)
    assert w.shape == (6, 3, 5, 5)
    # same stream as the flat request
    flat = W.named_tensor("m/c1/w", 6 * 3 * 25)
    np.testing.assert_array_equal(w.reshape(-1), flat)


def test_input_tensor_shape_range():
    x = W.input_tensor("m", 3, 8, 9)
    assert x.shape == (3, 8, 9)
    assert x.min() >= 0.0 and x.max() < 1.0
