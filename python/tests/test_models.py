"""L2 model zoo tests: shapes, parameter counts, and full forward passes
for the small models (large ImageNet models are shape-checked only —
interpret-mode Pallas on 224x224 inputs is build-time-scale work)."""

import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M
from compile.partition import shape_after


@pytest.mark.parametrize(
    "name,convs,fcs",
    [
        ("lenet", 2, 3),
        ("alexnet", 5, 3),
        ("vgg11", 8, 3),
        ("vgg13", 10, 3),
        ("vgg16", 13, 3),
        ("vgg19", 16, 3),
        ("vgg_mini", 3, 2),
    ],
)
def test_table1_op_counts(name, convs, fcs):
    md = M.by_name(name)
    assert sum(isinstance(o, M.Conv) for o in md.ops) == convs
    assert sum(isinstance(o, M.Dense) for o in md.ops) == fcs


@pytest.mark.parametrize("name", ["lenet", "alexnet", "vgg11", "vgg16", "vgg_mini"])
def test_shape_inference_chains(name):
    md = M.by_name(name)
    out = shape_after(md, len(md.ops), md.input_shape)
    assert out in [(10,), (1000,)]


def test_lenet_canonical_shapes():
    md = M.lenet()
    assert shape_after(md, 4, md.input_shape) == (16, 5, 5)
    assert shape_after(md, 5, md.input_shape) == (400,)


def test_alexnet_flatten_is_9216():
    md = M.alexnet()
    assert shape_after(md, 9, md.input_shape) == (9216,)


def test_param_counts_match_rust():
    # LeNet total params, frozen in rust zoo tests.
    md = M.lenet()
    total = sum(w.size + b.size for w, b in M.all_params(md))
    assert total == 156 + 2416 + 48120 + 10164 + 850


@pytest.mark.parametrize("name", ["lenet", "vgg_mini"])
def test_forward_runs_and_is_finite(name):
    md = M.by_name(name)
    params = [(jnp.asarray(w), jnp.asarray(b)) for w, b in M.all_params(md)]
    y = M.forward(md, jnp.asarray(M.model_input(md)), params)
    assert y.shape == (10,)
    assert np.isfinite(np.asarray(y)).all()


def test_lenet_logits_match_rust_reference():
    # Frozen from rust exec::compute::centralized_inference with the
    # mirrored weights — the cross-language anchor for the whole stack.
    md = M.lenet()
    params = [(jnp.asarray(w), jnp.asarray(b)) for w, b in M.all_params(md)]
    y = np.asarray(M.forward(md, jnp.asarray(M.model_input(md)), params))
    frozen = np.array(
        [-0.03345, 0.03065, 0.02081, 0.04125, -0.02507,
         -0.01543, 0.0036, 0.00526, -0.04151, 0.01823], np.float32
    )
    np.testing.assert_allclose(y, frozen, atol=1e-5)


def test_forward_accepts_flat_weights():
    md = M.vgg_mini()
    params = [
        (jnp.asarray(w).reshape(-1), jnp.asarray(b)) for w, b in M.all_params(md)
    ]
    y = M.forward(md, jnp.asarray(M.model_input(md)), params)
    assert y.shape == (10,)
