"""L1 correctness: Pallas kernels vs the pure-jnp oracles (ref.py).

Hypothesis sweeps shapes/strides/pads; every case asserts allclose.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import weights as W
from compile.kernels import conv2d, dense, maxpool2d, ref

SET = dict(max_examples=25, deadline=None)


def arr(name, shape, scale=1.0):
    return jnp.asarray(W.named_tensor(name, int(np.prod(shape)), scale).reshape(shape))


@given(
    c_in=st.integers(1, 5),
    c_out=st.integers(1, 9),
    k=st.integers(1, 5),
    stride=st.integers(1, 3),
    pad=st.integers(0, 2),
    hw=st.integers(5, 14),
    relu=st.booleans(),
    bias=st.booleans(),
    seed=st.integers(0, 10_000),
)
@settings(**SET)
def test_conv2d_matches_ref(c_in, c_out, k, stride, pad, hw, relu, bias, seed):
    if hw + 2 * pad < k:
        return
    x = arr(f"x{seed}", (c_in, hw, hw))
    w = arr(f"w{seed}", (c_out, c_in, k, k))
    b = arr(f"b{seed}", (c_out,)) if bias else None
    got = conv2d(x, w, b, stride=stride, pad_h=pad, pad_w=pad, relu=relu)
    want = ref.conv2d_ref(x, w, b, stride=stride, pad_h=pad, pad_w=pad, relu=relu)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@given(
    c_in=st.integers(1, 300),
    c_out=st.integers(1, 300),
    relu=st.booleans(),
    bias=st.booleans(),
    seed=st.integers(0, 10_000),
)
@settings(**SET)
def test_dense_matches_ref(c_in, c_out, relu, bias, seed):
    x = arr(f"dx{seed}", (c_in,))
    w = arr(f"dw{seed}", (c_out, c_in))
    b = arr(f"db{seed}", (c_out,)) if bias else None
    got = dense(x, w, b, relu=relu)
    want = ref.dense_ref(x, w, b, relu=relu)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@given(
    c=st.integers(1, 40),
    k=st.integers(1, 4),
    stride=st.integers(1, 3),
    hw=st.integers(4, 16),
    seed=st.integers(0, 10_000),
)
@settings(**SET)
def test_maxpool_matches_ref(c, k, stride, hw, seed):
    if hw < k:
        return
    x = arr(f"px{seed}", (c, hw, hw))
    got = maxpool2d(x, k=k, stride=stride)
    want = ref.maxpool2d_ref(x, k, stride)
    np.testing.assert_allclose(got, want, rtol=0, atol=0)


def test_conv_asymmetric_padding():
    # pad_h=0, pad_w=p — the row-shard configuration.
    x = arr("ax", (3, 10, 8))
    w = arr("aw", (4, 3, 3, 3))
    got = conv2d(x, w, None, stride=1, pad_h=0, pad_w=1)
    want = ref.conv2d_ref(x, w, None, stride=1, pad_h=0, pad_w=1)
    assert got.shape == (4, 8, 8)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_conv_oc_tile_not_dividing():
    # c_out=9 with default oc_tile=8 exercises padding+slice-back.
    x = arr("tx", (2, 6, 6))
    w = arr("tw", (9, 2, 3, 3))
    b = arr("tb", (9,))
    got = conv2d(x, w, b, pad_h=1, pad_w=1, relu=True)
    want = ref.conv2d_ref(x, w, b, pad_h=1, pad_w=1, relu=True)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_alexnet_style_overlapping_pool():
    x = arr("ox", (4, 13, 13))
    got = maxpool2d(x, k=3, stride=2)
    want = ref.maxpool2d_ref(x, 3, 2)
    assert got.shape == (4, 6, 6)
    np.testing.assert_allclose(got, want)


def test_dense_row_tile_not_dividing():
    x = arr("rx", (7,))
    w = arr("rw", (200, 7))
    got = dense(x, w, None, row_tile=128)
    want = ref.dense_ref(x, w, None)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("stride", [1, 2, 4])
def test_conv_strides_shapes(stride):
    x = arr("sx", (1, 16, 16))
    w = arr("sw", (2, 1, 3, 3))
    y = conv2d(x, w, None, stride=stride, pad_h=1, pad_w=1)
    expect_hw = (16 + 2 - 3) // stride + 1
    assert y.shape == (2, expect_hw, expect_hw)
