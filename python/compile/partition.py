"""Shard step builders — the python mirror of the executor's slice
semantics (`rust/src/exec/compute.rs`), used by ``aot.py`` to lower one
XLA executable per (stage, device) of the plans the rust coordinator
exported via ``iop emit-plans``.

Slice semantics (must stay in lock-step with the rust executor):

* ``full`` / ``replicate`` — head op + whole tail (flatten applied);
* ``oc``   — OC-sliced weights (+bias, +ReLU) then the tail;
* ``ic``   — IC-sliced *linear* part only (no bias/ReLU): partial sums;
  the post-reduction ``tail`` executable applies bias/ReLU/pool/flatten;
* ``rows`` — input is a pre-assembled row window (halo + zero padding
  materialized by the rust worker), conv runs with vertical padding 0,
  pools apply row-locally, flatten is deferred to assembly.

All weight parameters are *flat* f32 vectors (rank-1) — the rust side
slices with ``tensor::slice`` and feeds plain vectors; each builder
reshapes internally.
"""

from __future__ import annotations

from typing import Callable, List, Optional, Tuple

import jax.numpy as jnp

from .kernels import conv2d, dense, maxpool2d
from .model import Conv, Dense, Flatten, ModelDef, Pool

Shape = Tuple[int, ...]


def shape_after(model: ModelDef, upto: int, input_shape: Shape) -> Shape:
    """Shape after ops[0..upto) — mirrors rust shape inference."""
    c, h, w = input_shape
    flat: Optional[int] = None
    for op in model.ops[:upto]:
        if isinstance(op, Conv):
            h = (h + 2 * op.pad - op.k) // op.stride + 1
            w = (w + 2 * op.pad - op.k) // op.stride + 1
            c = op.c_out
        elif isinstance(op, Pool):
            h = (h - op.k) // op.stride + 1
            w = (w - op.k) // op.stride + 1
        elif isinstance(op, Flatten):
            flat = c * h * w
        elif isinstance(op, Dense):
            flat = op.c_out
    return (flat,) if flat is not None else (c, h, w)


def run_tail(model: ModelDef, op_idx: int, tail_end: int, x, skip_flatten: bool):
    for op in model.ops[op_idx + 1 : tail_end]:
        if isinstance(op, Pool):
            x = maxpool2d(x, k=op.k, stride=op.stride)
        elif isinstance(op, Flatten):
            if not skip_flatten:
                x = x.reshape(-1)
        else:
            raise TypeError(f"weighted op {op} in tail")
    return x


def build_step(
    model: ModelDef,
    op_idx: int,
    tail_end: int,
    dev: dict,
    in_shape: Shape,
) -> Tuple[Callable, List[Shape]]:
    """Build the jax step function + example input shapes for one device
    slice (a `devices[j]` record from plans.json)."""
    op = model.ops[op_idx]
    kind = dev["kind"]

    if kind in ("full", "replicate"):
        if isinstance(op, Conv):
            x_shape = (op.c_in, in_shape[1], in_shape[2])

            def fn(x, w, b):
                y = conv2d(
                    x,
                    w.reshape(op.c_out, op.c_in, op.k, op.k),
                    b,
                    stride=op.stride,
                    pad_h=op.pad,
                    pad_w=op.pad,
                    relu=op.relu,
                )
                return (run_tail(model, op_idx, tail_end, y, False),)

            return fn, [x_shape, (op.c_out * op.c_in * op.k * op.k,), (op.c_out,)]
        else:
            def fn(x, w, b):
                y = dense(x, w.reshape(op.c_out, op.c_in), b, relu=op.relu)
                return (run_tail(model, op_idx, tail_end, y, False),)

            return fn, [(op.c_in,), (op.c_out * op.c_in,), (op.c_out,)]

    if kind == "oc":
        n = dev["count"]
        if isinstance(op, Conv):
            x_shape = (op.c_in, in_shape[1], in_shape[2])

            def fn(x, w, b):
                y = conv2d(
                    x,
                    w.reshape(n, op.c_in, op.k, op.k),
                    b,
                    stride=op.stride,
                    pad_h=op.pad,
                    pad_w=op.pad,
                    relu=op.relu,
                )
                return (run_tail(model, op_idx, tail_end, y, False),)

            return fn, [x_shape, (n * op.c_in * op.k * op.k,), (n,)]
        else:
            def fn(x, w, b):
                y = dense(x, w.reshape(n, op.c_in), b, relu=op.relu)
                return (run_tail(model, op_idx, tail_end, y, False),)

            return fn, [(op.c_in,), (n * op.c_in,), (n,)]

    if kind == "ic":
        n = dev["count"]
        if isinstance(op, Conv):
            x_shape = (n, in_shape[1], in_shape[2])

            def fn(x, w):
                return (
                    conv2d(
                        x,
                        w.reshape(op.c_out, n, op.k, op.k),
                        None,
                        stride=op.stride,
                        pad_h=op.pad,
                        pad_w=op.pad,
                        relu=False,
                    ),
                )

            return fn, [x_shape, (op.c_out * n * op.k * op.k,)]
        else:
            def fn(x, w):
                return (dense(x, w.reshape(op.c_out, n), None, relu=False),)

            return fn, [(n,), (op.c_out * n,)]

    if kind == "rows":
        assert isinstance(op, Conv), "row shards are conv-only"
        win_h = dev["win_hi"] - dev["win_lo"]
        x_shape = (op.c_in, win_h, in_shape[2])

        def fn(x, w, b):
            y = conv2d(
                x,
                w.reshape(op.c_out, op.c_in, op.k, op.k),
                b,
                stride=op.stride,
                pad_h=0,  # vertical halo/padding pre-materialized
                pad_w=op.pad,
                relu=op.relu,
            )
            return (run_tail(model, op_idx, tail_end, y, True),)

        return fn, [x_shape, (op.c_out * op.c_in * op.k * op.k,), (op.c_out,)]

    raise ValueError(f"no executable for slice kind {kind!r}")


def build_tail(model: ModelDef, op_idx: int, tail_end: int, raw_shape: Shape) -> Tuple[Callable, List[Shape]]:
    """Post-reduction tail: bias + ReLU + tail ops on the reduced raw sum."""
    op = model.ops[op_idx]

    if isinstance(op, Conv):
        def fn(raw, b):
            y = raw + b[:, None, None]
            if op.relu:
                y = jnp.maximum(y, 0.0)
            return (run_tail(model, op_idx, tail_end, y, False),)

        return fn, [raw_shape, (op.c_out,)]
    else:
        def fn(raw, b):
            y = raw + b
            if op.relu:
                y = jnp.maximum(y, 0.0)
            return (run_tail(model, op_idx, tail_end, y, False),)

        return fn, [raw_shape, (op.c_out,)]
