"""Deterministic weight generation — the python half of the mirrored PRNG.

Bit-for-bit identical to ``rust/src/util/prng.rs`` (SplitMix64 seeded by
FNV-1a of a tensor name; f32 values from the top 24 bits). The rust
coordinator generates/slices weights with the same streams, so PJRT shard
executables see exactly the numbers the python oracle validated.
"""

from __future__ import annotations

import numpy as np

_M64 = (1 << 64) - 1

#: Default weight scale (mirrors prng.rs WEIGHT_SCALE in tensor/init.rs).
WEIGHT_SCALE = np.float32(0.05)


def fnv1a(name: str) -> int:
    """FNV-1a 64-bit hash (stable across languages)."""
    h = 0xCBF29CE484222325
    for b in name.encode("utf-8"):
        h ^= b
        h = (h * 0x100000001B3) & _M64
    return h


class SplitMix64:
    """SplitMix64 PRNG (Vigna, 2015) — integer-only, trivially portable."""

    def __init__(self, seed: int):
        self.state = seed & _M64

    @classmethod
    def from_name(cls, name: str) -> "SplitMix64":
        return cls(fnv1a(name))

    def next_u64(self) -> int:
        self.state = (self.state + 0x9E3779B97F4A7C15) & _M64
        z = self.state
        z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & _M64
        z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & _M64
        return (z ^ (z >> 31)) & _M64

    def fill_u24(self, n: int) -> np.ndarray:
        """n raw 24-bit outputs (the f32 mantissa source)."""
        out = np.empty(n, dtype=np.uint32)
        for i in range(n):
            out[i] = self.next_u64() >> 40
        return out


def uniform01(name: str, n: int) -> np.ndarray:
    """n float32 values in [0, 1): ``top24 / 2^24`` exactly as rust does."""
    bits = SplitMix64.from_name(name).fill_u24(n)
    return bits.astype(np.float32) / np.float32(16777216.0)


def named_tensor(name: str, n: int, scale: float = WEIGHT_SCALE) -> np.ndarray:
    """n float32 values in [-scale, scale) — rust's ``named_tensor``."""
    u = uniform01(name, n)
    return (u * np.float32(2.0) - np.float32(1.0)) * np.float32(scale)


# ---- model-level helpers (mirror rust tensor::init naming) ----


def conv_weight(model: str, op: str, c_out: int, c_in: int, kh: int, kw: int) -> np.ndarray:
    """OIHW conv weight for ``{model}/{op}/w``."""
    flat = named_tensor(f"{model}/{op}/w", c_out * c_in * kh * kw)
    return flat.reshape(c_out, c_in, kh, kw)


def dense_weight(model: str, op: str, c_out: int, c_in: int) -> np.ndarray:
    """(c_out, c_in) dense weight for ``{model}/{op}/w``."""
    return named_tensor(f"{model}/{op}/w", c_out * c_in).reshape(c_out, c_in)


def bias(model: str, op: str, c_out: int) -> np.ndarray:
    return named_tensor(f"{model}/{op}/b", c_out)


def input_tensor(model: str, c: int, h: int, w: int) -> np.ndarray:
    """Synthetic inference input in [0, 1) for ``{model}/input``."""
    return uniform01(f"{model}/input", c * h * w).reshape(c, h, w)
