"""L2 — the JAX model zoo, mirroring ``rust/src/model/zoo`` exactly.

Each model is a declarative op list (the same `(c_in, c_out, k, s, p)`
tuples as the rust IR) plus a forward pass built *only* from the L1
Pallas kernels, so every exported HLO contains the kernel lowerings.

Weights come from ``weights.py`` (mirrored PRNG) so rust-side
distributed execution is numerically comparable.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from . import weights as W
from .kernels import conv2d, dense, maxpool2d


@dataclass(frozen=True)
class Conv:
    name: str
    c_in: int
    c_out: int
    k: int
    stride: int
    pad: int
    relu: bool = True


@dataclass(frozen=True)
class Dense:
    name: str
    c_in: int
    c_out: int
    relu: bool


@dataclass(frozen=True)
class Pool:
    name: str
    k: int
    stride: int


@dataclass(frozen=True)
class Flatten:
    name: str = "flatten"


Op = object  # Conv | Dense | Pool | Flatten


@dataclass(frozen=True)
class ModelDef:
    name: str
    input_shape: Tuple[int, int, int]  # (C, H, W)
    ops: Tuple[Op, ...]

    def weighted_ops(self) -> List[Op]:
        return [o for o in self.ops if isinstance(o, (Conv, Dense))]


def lenet() -> ModelDef:
    return ModelDef(
        "lenet",
        (1, 28, 28),
        (
            Conv("conv1", 1, 6, 5, 1, 2),
            Pool("pool1", 2, 2),
            Conv("conv2", 6, 16, 5, 1, 0),
            Pool("pool2", 2, 2),
            Flatten(),
            Dense("fc1", 400, 120, True),
            Dense("fc2", 120, 84, True),
            Dense("fc3", 84, 10, False),
        ),
    )


def alexnet() -> ModelDef:
    return ModelDef(
        "alexnet",
        (3, 224, 224),
        (
            Conv("conv1", 3, 96, 11, 4, 2),
            Pool("pool1", 3, 2),
            Conv("conv2", 96, 256, 5, 1, 2),
            Pool("pool2", 3, 2),
            Conv("conv3", 256, 384, 3, 1, 1),
            Conv("conv4", 384, 384, 3, 1, 1),
            Conv("conv5", 384, 256, 3, 1, 1),
            Pool("pool5", 3, 2),
            Flatten(),
            Dense("fc6", 9216, 4096, True),
            Dense("fc7", 4096, 4096, True),
            Dense("fc8", 4096, 1000, False),
        ),
    )


def vgg(depth: int) -> ModelDef:
    cfg = {
        11: [(64, 1), (128, 1), (256, 2), (512, 2), (512, 2)],
        13: [(64, 2), (128, 2), (256, 2), (512, 2), (512, 2)],
        16: [(64, 2), (128, 2), (256, 3), (512, 3), (512, 3)],
        19: [(64, 2), (128, 2), (256, 4), (512, 4), (512, 4)],
    }[depth]
    ops: List[Op] = []
    c_in = 3
    for block, (width, n) in enumerate(cfg):
        for i in range(n):
            ops.append(Conv(f"conv{block + 1}_{i + 1}", c_in, width, 3, 1, 1))
            c_in = width
        ops.append(Pool(f"pool{block + 1}", 2, 2))
    ops.append(Flatten())
    ops.append(Dense("fc1", 512 * 7 * 7, 4096, True))
    ops.append(Dense("fc2", 4096, 4096, True))
    ops.append(Dense("fc3", 4096, 1000, False))
    return ModelDef(f"vgg{depth}", (3, 224, 224), tuple(ops))


def vgg_mini() -> ModelDef:
    return ModelDef(
        "vgg_mini",
        (3, 32, 32),
        (
            Conv("conv1", 3, 8, 3, 1, 1),
            Pool("pool1", 2, 2),
            Conv("conv2", 8, 16, 3, 1, 1),
            Pool("pool2", 2, 2),
            Conv("conv3", 16, 32, 3, 1, 1),
            Pool("pool3", 2, 2),
            Flatten(),
            Dense("fc1", 512, 64, True),
            Dense("fc2", 64, 10, False),
        ),
    )


def by_name(name: str) -> ModelDef:
    table = {
        "lenet": lenet,
        "alexnet": alexnet,
        "vgg11": lambda: vgg(11),
        "vgg13": lambda: vgg(13),
        "vgg16": lambda: vgg(16),
        "vgg19": lambda: vgg(19),
        "vgg_mini": vgg_mini,
    }
    return table[name]()


# ---------------- parameters ----------------


def op_params(model: ModelDef, op: Op) -> Tuple[np.ndarray, np.ndarray]:
    """Deterministic (w, b) for a weighted op (mirrored PRNG streams)."""
    if isinstance(op, Conv):
        return (
            W.conv_weight(model.name, op.name, op.c_out, op.c_in, op.k, op.k),
            W.bias(model.name, op.name, op.c_out),
        )
    if isinstance(op, Dense):
        return (
            W.dense_weight(model.name, op.name, op.c_out, op.c_in),
            W.bias(model.name, op.name, op.c_out),
        )
    raise TypeError(op)


def all_params(model: ModelDef) -> List[Tuple[np.ndarray, np.ndarray]]:
    return [op_params(model, o) for o in model.weighted_ops()]


def model_input(model: ModelDef) -> np.ndarray:
    c, h, w = model.input_shape
    return W.input_tensor(model.name, c, h, w)


# ---------------- forward passes (Pallas-kernel based) ----------------


def apply_op(op: Op, x, w=None, b=None):
    """Apply one op; weighted ops consume (w, b)."""
    if isinstance(op, Conv):
        return conv2d(x, w, b, stride=op.stride, pad_h=op.pad, pad_w=op.pad, relu=op.relu)
    if isinstance(op, Dense):
        return dense(x, w, b, relu=op.relu)
    if isinstance(op, Pool):
        return maxpool2d(x, k=op.k, stride=op.stride)
    if isinstance(op, Flatten):
        return x.reshape(-1)
    raise TypeError(op)


def forward(model: ModelDef, x, params):
    """Full centralized forward pass. ``params``: [(w, b)] per weighted op,
    each flattened or shaped (both accepted)."""
    it = iter(params)
    for op in model.ops:
        if isinstance(op, (Conv, Dense)):
            w, b = next(it)
            w = reshape_weight(op, w)
            x = apply_op(op, x, w, b)
        else:
            x = apply_op(op, x)
    return x


def reshape_weight(op: Op, w):
    """Accept flat weight vectors (the AOT parameter convention)."""
    w = jnp.asarray(w)
    if isinstance(op, Conv):
        return w.reshape(op.c_out, op.c_in, op.k, op.k)
    if isinstance(op, Dense):
        return w.reshape(op.c_out, op.c_in)
    raise TypeError(op)
