"""Pallas max-pooling kernel (L1).

Grid over channel tiles; inside a step the k×k window taps are unrolled
(static python loops) into strided slices combined with `jnp.maximum` —
this handles overlapping windows (AlexNet's 3×3/stride-2 pools) as well
as the tiling 2×2/stride-2 case.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

#: Channels per grid step.
DEFAULT_C_TILE = 16


def _pool_kernel(x_ref, o_ref, *, k, stride, out_h, out_w):
    x = x_ref[...]  # (C_t, H, W)
    acc = None
    for ky in range(k):
        for kx in range(k):
            xs = jax.lax.slice(
                x,
                (0, ky, kx),
                (x.shape[0], ky + (out_h - 1) * stride + 1, kx + (out_w - 1) * stride + 1),
                (1, stride, stride),
            )
            acc = xs if acc is None else jnp.maximum(acc, xs)
    o_ref[...] = acc


@functools.partial(jax.jit, static_argnames=("k", "stride", "c_tile"))
def maxpool2d(x, *, k, stride, c_tile=DEFAULT_C_TILE):
    """Pallas maxpool. ``x``: (C,H,W); window ``k``, stride ``stride``."""
    c, h, w = x.shape
    out_h = (h - k) // stride + 1
    out_w = (w - k) // stride + 1
    c_tile = min(c_tile, c)
    pad = (-c) % c_tile
    x_p = jnp.pad(x, ((0, pad), (0, 0), (0, 0)), constant_values=-jnp.inf)
    n_tiles = (c + pad) // c_tile

    y = pl.pallas_call(
        functools.partial(_pool_kernel, k=k, stride=stride, out_h=out_h, out_w=out_w),
        grid=(n_tiles,),
        in_specs=[pl.BlockSpec((c_tile, h, w), lambda i: (i, 0, 0))],
        out_specs=pl.BlockSpec((c_tile, out_h, out_w), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((c + pad, out_h, out_w), jnp.float32),
        interpret=True,
    )(x_p)
    return y[:c]
