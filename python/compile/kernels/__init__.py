"""L1 Pallas kernels (interpret=True) + their pure-jnp oracles.

Public surface: ``conv2d``, ``dense``, ``maxpool2d`` (Pallas) and
``ref`` (the oracle module). The L2 model layer (`compile.model`) calls
only these, so the kernels lower into every exported HLO artifact.
"""

from . import ref
from .conv2d import conv2d
from .matmul import dense
from .pool import maxpool2d

__all__ = ["conv2d", "dense", "maxpool2d", "ref"]
