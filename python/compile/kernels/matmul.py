"""Pallas dense-layer (matmul) kernel (L1).

Grid over output-row tiles: each step holds one `(ROW_TILE, c_in)` block
of the weight matrix plus the input vector in VMEM and performs an
MXU-shaped `(ROW_TILE, c_in) × (c_in,)` contraction, with bias and ReLU
fused. Used for every FC operator in the zoo, including the OC/IC shard
variants (sliced weights / sliced inputs are handled by the caller — the
kernel is oblivious).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

#: Output rows per grid step (MXU-friendly, small enough for any FC here).
DEFAULT_ROW_TILE = 128


def _dense_kernel(x_ref, w_ref, b_ref, o_ref, *, relu):
    x = x_ref[...]  # (c_in,)
    w = w_ref[...]  # (ROW_TILE, c_in)
    y = w @ x
    if b_ref is not None:
        y = y + b_ref[...]
    if relu:
        y = jnp.maximum(y, 0.0)
    o_ref[...] = y


def _dense_kernel_nobias(x_ref, w_ref, o_ref, *, relu):
    _dense_kernel(x_ref, w_ref, None, o_ref, relu=relu)


@functools.partial(jax.jit, static_argnames=("relu", "row_tile"))
def dense(x, w, b=None, *, relu=False, row_tile=DEFAULT_ROW_TILE):
    """Pallas dense layer. ``x``: (c_in,); ``w``: (c_out, c_in); ``b``: (c_out,)?"""
    c_out, c_in = w.shape
    assert x.shape == (c_in,), f"input {x.shape} != ({c_in},)"
    row_tile = min(row_tile, c_out)
    pad = (-c_out) % row_tile
    w_p = jnp.pad(w, ((0, pad), (0, 0)))
    b_p = None if b is None else jnp.pad(b, (0, pad))
    n_tiles = (c_out + pad) // row_tile

    in_specs = [
        pl.BlockSpec((c_in,), lambda i: (0,)),
        pl.BlockSpec((row_tile, c_in), lambda i: (i, 0)),
    ]
    args = [x, w_p]
    if b is None:
        kernel = functools.partial(_dense_kernel_nobias, relu=relu)
    else:
        kernel = functools.partial(_dense_kernel, relu=relu)
        in_specs.append(pl.BlockSpec((row_tile,), lambda i: (i,)))
        args.append(b_p)

    y = pl.pallas_call(
        kernel,
        grid=(n_tiles,),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((row_tile,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((c_out + pad,), jnp.float32),
        interpret=True,
    )(*args)
    return y[:c_out]
