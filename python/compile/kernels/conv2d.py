"""Pallas direct-convolution kernel (L1).

TPU-shaped structure, CPU-interpretable execution (``interpret=True`` —
the CPU PJRT plugin cannot run Mosaic custom-calls; see DESIGN.md
§Hardware-Adaptation):

* the grid runs over **output-channel tiles** — each grid step keeps one
  OC block of the OIHW weights plus the whole (padded) input window in
  VMEM, which is exactly the blocking a TPU would want for these small
  IoT CNNs (input plane ≪ 16 MiB VMEM);
* inside a step the k_h·k_w taps are unrolled (static python loops) into
  strided slices, each contributing an ``einsum`` over input channels —
  an MXU-shaped contraction ``(OC_t, IC) × (IC, H·W)``;
* bias add + optional ReLU are fused into the same kernel.

The partitioned variants the paper needs fall out of the same kernel:
an OC shard is just a call with sliced weights; an IC shard is a call
with sliced input/weights and ``bias=None, relu=False`` (partial sums).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

#: Output channels handled per grid step. 8 keeps the per-step weight
#: block + accumulator comfortably inside a TPU core's VMEM for every
#: layer in the zoo (see DESIGN.md §Perf for the block-size sweep).
DEFAULT_OC_TILE = 8


def _conv_kernel(x_ref, w_ref, b_ref, o_ref, *, k_h, k_w, stride, relu):
    """One OC tile: full input in VMEM, unrolled taps, fused bias/ReLU."""
    x = x_ref[...]  # (C, Hp, Wp) — pre-padded input window
    w = w_ref[...]  # (OC_t, C, k_h, k_w)
    oc_t, _, _, _ = w.shape
    _, h_p, w_p = x.shape
    out_h = (h_p - k_h) // stride + 1
    out_w = (w_p - k_w) // stride + 1

    acc = jnp.zeros((oc_t, out_h * out_w), dtype=jnp.float32)
    for ky in range(k_h):
        for kx in range(k_w):
            # strided input window for this tap: (C, out_h, out_w)
            xs = jax.lax.slice(
                x,
                (0, ky, kx),
                (x.shape[0], ky + (out_h - 1) * stride + 1, kx + (out_w - 1) * stride + 1),
                (1, stride, stride),
            )
            # MXU-shaped contraction over input channels
            acc = acc + jnp.einsum(
                "oc,cp->op", w[:, :, ky, kx], xs.reshape(x.shape[0], -1)
            )
    y = acc.reshape(oc_t, out_h, out_w)
    if b_ref is not None:
        y = y + b_ref[...][:, None, None]
    if relu:
        y = jnp.maximum(y, 0.0)
    o_ref[...] = y


@functools.partial(
    jax.jit,
    static_argnames=("stride", "pad_h", "pad_w", "relu", "oc_tile"),
)
def conv2d(x, w, b=None, *, stride=1, pad_h=0, pad_w=0, relu=False, oc_tile=DEFAULT_OC_TILE):
    """Pallas conv2d. ``x``: (C,H,W) f32; ``w``: (O,I,kh,kw); ``b``: (O,)?"""
    c_out, c_in, k_h, k_w = w.shape
    assert x.shape[0] == c_in, f"input channels {x.shape[0]} != {c_in}"
    xp = jnp.pad(x, ((0, 0), (pad_h, pad_h), (pad_w, pad_w)))
    out_h = (xp.shape[1] - k_h) // stride + 1
    out_w = (xp.shape[2] - k_w) // stride + 1

    # Grid over OC tiles; pad OC up to a tile multiple, slice back after.
    oc_tile = min(oc_tile, c_out)
    oc_pad = (-c_out) % oc_tile
    w_p = jnp.pad(w, ((0, oc_pad), (0, 0), (0, 0), (0, 0)))
    b_p = None if b is None else jnp.pad(b, (0, oc_pad))
    n_tiles = (c_out + oc_pad) // oc_tile

    kernel = functools.partial(_conv_kernel, k_h=k_h, k_w=k_w, stride=stride, relu=relu)
    in_specs = [
        pl.BlockSpec(xp.shape, lambda i: (0, 0, 0)),  # full input each step
        pl.BlockSpec((oc_tile, c_in, k_h, k_w), lambda i: (i, 0, 0, 0)),
    ]
    args = [xp, w_p]
    if b is None:
        kernel = functools.partial(_kernel_nobias, inner=kernel)
    else:
        in_specs.append(pl.BlockSpec((oc_tile,), lambda i: (i,)))
        args.append(b_p)

    y = pl.pallas_call(
        kernel,
        grid=(n_tiles,),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((oc_tile, out_h, out_w), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((c_out + oc_pad, out_h, out_w), jnp.float32),
        interpret=True,
    )(*args)
    return y[:c_out]


def _kernel_nobias(x_ref, w_ref, o_ref, *, inner):
    inner(x_ref, w_ref, None, o_ref)
