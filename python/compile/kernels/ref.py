"""Pure-jnp reference oracles for the Pallas kernels (L1 correctness).

Everything here is the *definition of correct* for the kernel layer: the
Pallas kernels in this package are asserted against these functions by
``python/tests/test_kernels.py`` (hypothesis sweeps), and the rust
reference ops implement the same semantics independently.

Shapes follow the coordinator's conventions: activations are CHW (batch
elided), conv weights OIHW, dense weights (c_out, c_in).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def conv2d_ref(x, w, b=None, *, stride=1, pad_h=0, pad_w=0, relu=False):
    """Direct 2-D convolution. ``x``: (C,H,W); ``w``: (O,I,kh,kw)."""
    y = jax.lax.conv_general_dilated(
        x[None],  # NCHW
        w,
        window_strides=(stride, stride),
        padding=((pad_h, pad_h), (pad_w, pad_w)),
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
    )[0]
    if b is not None:
        y = y + b[:, None, None]
    if relu:
        y = jnp.maximum(y, 0.0)
    return y


def maxpool2d_ref(x, k, stride):
    """Max pooling, window ``k``, stride ``stride``, no padding."""
    return jax.lax.reduce_window(
        x,
        -jnp.inf,
        jax.lax.max,
        window_dimensions=(1, k, k),
        window_strides=(1, stride, stride),
        padding="VALID",
    )


def dense_ref(x, w, b=None, *, relu=False):
    """Dense layer. ``x``: (c_in,); ``w``: (c_out, c_in)."""
    y = w @ x
    if b is not None:
        y = y + b
    if relu:
        y = jnp.maximum(y, 0.0)
    return y


def relu_ref(x):
    return jnp.maximum(x, 0.0)
