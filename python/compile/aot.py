"""AOT compiler: JAX/Pallas -> HLO *text* artifacts + manifest.json.

Run once by ``make artifacts`` (never at inference time):

1. ``iop emit-plans`` (rust) exports the canonical partition plans as
   ``artifacts/plans.json``;
2. this module lowers, per (model, strategy, stage, device), the shard
   step functions of ``partition.py`` — plus the post-reduction tails and
   the centralized whole-network executables — to HLO text;
3. ``manifest.json`` maps semantic keys to files + shapes for the rust
   runtime (`rust/src/runtime/manifest.rs`).

HLO **text** (not serialized protos) is the interchange format: jax>=0.5
emits 64-bit instruction ids that xla_extension 0.5.1 rejects; the text
parser reassigns ids (see /opt/xla-example/README.md).

Identical step functions are deduplicated by content hash, so e.g. three
equal OC shards share one executable file.
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as M
from .partition import build_step, build_tail, shape_after


def to_hlo_text(fn, arg_shapes: List[Tuple[int, ...]]) -> str:
    """Lower ``fn(*args)`` (returning a tuple) to HLO text."""
    specs = [jax.ShapeDtypeStruct(s, jnp.float32) for s in arg_shapes]
    lowered = jax.jit(fn).lower(*specs)
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


class Exporter:
    """Writes deduplicated HLO files + manifest entries."""

    def __init__(self, out_dir: str):
        self.out_dir = out_dir
        self.entries: Dict[str, dict] = {}
        self._dedup: Dict[str, str] = {}  # content hash -> file name
        os.makedirs(out_dir, exist_ok=True)

    def add(self, key: str, fn, in_shapes, out_shape) -> None:
        text = to_hlo_text(fn, in_shapes)
        h = hashlib.sha256(text.encode()).hexdigest()[:16]
        fname = self._dedup.get(h)
        if fname is None:
            fname = f"{h}.hlo.txt"
            with open(os.path.join(self.out_dir, fname), "w") as f:
                f.write(text)
            self._dedup[h] = fname
        self.entries[key] = {
            "file": fname,
            "inputs": [list(s) for s in in_shapes],
            "output": list(out_shape),
        }

    def write_manifest(self) -> None:
        with open(os.path.join(self.out_dir, "manifest.json"), "w") as f:
            json.dump({"entries": self.entries}, f, indent=1, sort_keys=True)


def out_shape_of(model: M.ModelDef, op_idx: int, tail_end: int, dev: dict, in_shape):
    """Output shape of a device's step (mirrors the executor semantics)."""
    op = model.ops[op_idx]
    kind = dev["kind"]

    def tail_shape(shp, skip_flatten=False):
        flat = None
        for t in model.ops[op_idx + 1 : tail_end]:
            if isinstance(t, M.Pool):
                shp = (shp[0], (shp[1] - t.k) // t.stride + 1, (shp[2] - t.k) // t.stride + 1)
            elif isinstance(t, M.Flatten) and not skip_flatten:
                flat = shp[0] * shp[1] * shp[2]
        return (flat,) if flat is not None else shp

    if isinstance(op, M.Dense):
        c_out = dev.get("count") if kind == "oc" else op.c_out
        return (c_out,)

    # conv head
    _, h, w = in_shape
    out_w = (w + 2 * op.pad - op.k) // op.stride + 1
    if kind == "rows":
        win_h = dev["win_hi"] - dev["win_lo"]
        out_h = (win_h - op.k) // op.stride + 1  # pad_h = 0 on the window
        return tail_shape((op.c_out, out_h, out_w), skip_flatten=True)
    out_h = (h + 2 * op.pad - op.k) // op.stride + 1
    if kind == "ic":
        return (op.c_out, out_h, out_w)  # raw partial, no tail
    c_out = dev["count"] if kind == "oc" else op.c_out
    return tail_shape((c_out, out_h, out_w))


def export_model(ex: Exporter, name: str, plan_doc: dict) -> None:
    model = M.by_name(name)

    # 1) centralized whole-network executable
    wops = model.weighted_ops()

    def central(x, *flat_params):
        params = []
        for i in range(len(wops)):
            params.append((flat_params[2 * i], flat_params[2 * i + 1]))
        return (M.forward(model, x, params),)

    in_shapes: List[Tuple[int, ...]] = [model.input_shape]
    for op in wops:
        if isinstance(op, M.Conv):
            in_shapes.append((op.c_out * op.c_in * op.k * op.k,))
        else:
            in_shapes.append((op.c_out * op.c_in,))
        in_shapes.append((op.c_out,))
    out = shape_after(model, len(model.ops), model.input_shape)
    ex.add(f"{name}/central", central, in_shapes, out)

    # 2) per-strategy shard executables
    for strat, plan in plan_doc["strategies"].items():
        for st in plan["stages"]:
            op_idx = st["op_idx"]
            tail_end = st["tail_end"]
            in_shape = tuple(st["in_shape"])
            if len(in_shape) == 3 and in_shape[1] == 1 and in_shape[2] == 1:
                in_shape = (in_shape[0],)
            si = st["stage"]
            any_ic = False
            for d, dev in enumerate(st["devices"]):
                if dev["kind"] == "idle":
                    continue
                if dev["kind"] == "ic":
                    any_ic = True
                fn, shapes = build_step(model, op_idx, tail_end, dev, in_shape)
                out = out_shape_of(model, op_idx, tail_end, dev, in_shape)
                ex.add(f"{name}/{strat}/s{si}/d{d}", fn, shapes, out)
            if any_ic:
                raw = out_shape_of(
                    model, op_idx, tail_end, {"kind": "ic", "count": 1}, in_shape
                )
                fn, shapes = build_tail(model, op_idx, tail_end, raw)
                out = out_shape_of(model, op_idx, tail_end, {"kind": "full"}, in_shape)
                ex.add(f"{name}/{strat}/s{si}/tail", fn, shapes, out)


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--plans", default="../artifacts/plans.json")
    p.add_argument("--out", default="../artifacts")
    args = p.parse_args()

    with open(args.plans) as f:
        plans = json.load(f)

    ex = Exporter(args.out)
    for name, doc in plans.items():
        print(f"exporting {name} ...")
        export_model(ex, name, doc)
    ex.write_manifest()
    n_files = len(set(e["file"] for e in ex.entries.values()))
    print(f"wrote {len(ex.entries)} manifest entries ({n_files} unique HLO files)")


if __name__ == "__main__":
    main()
