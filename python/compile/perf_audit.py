"""L1/L2 performance audit (EXPERIMENTS.md §Perf inputs).

L1: per-conv-layer VMEM footprint + MXU-tile fit of the Pallas conv
kernel's BlockSpec, as a function of the OC tile size — interpret=True
gives no TPU wallclock, so the structural estimate is the optimization
signal (DESIGN.md §Hardware-Adaptation) — plus an interpret-mode timing
sweep as a secondary sanity signal.

L2: op histogram of the exported HLO artifacts — checks that XLA fused
the kernels (few large fusions, no stray transposes/copies on the hot
path).

Run:  cd python && python -m compile.perf_audit
"""

from __future__ import annotations

import collections
import json
import os
import re
import time

import jax.numpy as jnp
import numpy as np

from . import model as M
from . import weights as W
from .kernels import conv2d

VMEM_BYTES = 16 * 2**20  # per-core VMEM on current TPUs
MXU = 128  # systolic array edge


def conv_vmem(model: M.ModelDef, oc_tile: int):
    """Per-grid-step VMEM bytes for each conv layer: padded input block +
    weight OC-block + bias + output block (all f32)."""
    rows = []
    c, h, w = model.input_shape
    for op in model.ops:
        if isinstance(op, M.Conv):
            hp, wp = h + 2 * op.pad, w + 2 * op.pad
            out_h = (hp - op.k) // op.stride + 1
            out_w = (wp - op.k) // op.stride + 1
            t = min(oc_tile, op.c_out)
            x_b = op.c_in * hp * wp * 4
            w_b = t * op.c_in * op.k * op.k * 4
            o_b = t * out_h * out_w * 4
            total = x_b + w_b + o_b + t * 4
            rows.append((op.name, x_b, w_b, o_b, total, total <= VMEM_BYTES,
                         (t, op.c_in)))
            c, h, w = op.c_out, out_h, out_w
        elif isinstance(op, M.Pool):
            h = (h - op.k) // op.stride + 1
            w = (w - op.k) // op.stride + 1
    return rows


def audit_vmem():
    print("== L1: Pallas conv BlockSpec VMEM audit ==")
    for name in ["lenet", "alexnet", "vgg11"]:
        md = M.by_name(name)
        for tile in [4, 8, 16, 32]:
            rows = conv_vmem(md, tile)
            worst = max(rows, key=lambda r: r[4])
            fits = all(r[5] for r in rows)
            print(
                f"  {name:<8} oc_tile={tile:<3} worst layer {worst[0]:<8} "
                f"{worst[4]/2**20:6.2f} MiB of {VMEM_BYTES/2**20:.0f} MiB "
                f"({'fits' if fits else 'OVERFLOWS'}); "
                f"MXU contraction ({worst[6][0]}x{worst[6][1]}) vs {MXU}x{MXU}"
            )


def sweep_interpret_timing():
    print("\n== L1: interpret-mode timing sweep (structure sanity, not TPU perf) ==")
    md = M.by_name("vgg_mini")
    x = jnp.asarray(W.input_tensor("sweep", 3, 32, 32))
    wt = jnp.asarray(W.conv_weight("sweep", "c", 8, 3, 3, 3))
    b = jnp.asarray(W.bias("sweep", "c", 8))
    for tile in [2, 4, 8]:
        y = conv2d(x, wt, b, pad_h=1, pad_w=1, relu=True, oc_tile=tile)
        y.block_until_ready()
        t0 = time.perf_counter()
        for _ in range(20):
            conv2d(x, wt, b, pad_h=1, pad_w=1, relu=True, oc_tile=tile).block_until_ready()
        dt = (time.perf_counter() - t0) / 20
        print(f"  conv1(vgg_mini) oc_tile={tile}: {dt*1e3:.2f} ms/call (interpret)")


def audit_hlo(art_dir: str):
    print("\n== L2: HLO artifact audit (op histogram per executable) ==")
    man_path = os.path.join(art_dir, "manifest.json")
    if not os.path.exists(man_path):
        print("  (artifacts not built — run `make artifacts`)")
        return
    man = json.load(open(man_path))
    files = sorted(set(e["file"] for e in man["entries"].values()))
    op_re = re.compile(r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*[^=]*?\b([a-z][a-z0-9\-]*)\(")
    total_hist = collections.Counter()
    worst = None
    for f in files:
        hist = collections.Counter()
        for line in open(os.path.join(art_dir, f)):
            m = op_re.match(line)
            if m:
                hist[m.group(1)] += 1
        total_hist.update(hist)
        n = sum(hist.values())
        if worst is None or n > worst[1]:
            worst = (f, n, hist)
    print(f"  {len(files)} unique executables; total op histogram (top 12):")
    for op, n in total_hist.most_common(12):
        print(f"    {op:<22} {n}")
    # red flags for the CPU/PJRT hot path
    flags = {k: total_hist[k] for k in ("transpose", "copy", "sort") if total_hist[k]}
    print(f"  red-flag ops: {flags if flags else 'none'}")
    print(f"  largest executable: {worst[0]} ({worst[1]} ops)")


if __name__ == "__main__":
    audit_vmem()
    sweep_interpret_timing()
    audit_hlo(os.path.join(os.path.dirname(__file__), "..", "..", "artifacts"))
